#!/usr/bin/env bash
# CI gate for the workspace: tier-1 verify + python tests + fmt + lints.
#
#   ./ci.sh          # build, test, pytest (L1/L2), fmt --check, clippy
#   ./ci.sh fast     # tier-1 only (build + test)
#
# Needs a Rust toolchain (cargo); the python (L1/L2) test step and the
# fmt/clippy steps are skipped with a warning when the corresponding
# component is missing.
set -euo pipefail
cd "$(dirname "$0")"

run() { echo "+ $*"; "$@"; }

run cargo build --release
run cargo test -q

if [ "${1:-}" = "fast" ]; then
    exit 0
fi

# test-inventory audit: the skip-clean integration tests print a
# standardized "skipping: artifact '<name>' unavailable" line; when the
# artifacts directory exists, none of those skips may name an artifact
# that IS on disk (a silently-hollowed test is a CI bug, not a skip).
# Same (debug) profile as the tier-1 run above, so nothing recompiles —
# only the integration binary re-runs, un-captured, for the audit log.
if [ -d artifacts ] && python3 -c "import sys" >/dev/null 2>&1; then
    echo "+ cargo test --test integration -- --nocapture | skip_audit"
    INTEG_LOG=$(cargo test --test integration -- --nocapture 2>&1) || {
        echo "$INTEG_LOG"
        exit 1
    }
    echo "$INTEG_LOG" | python3 tools/skip_audit.py artifacts
fi

# §2g observability lanes: (a) the Rust `Event` enum and the Python trace
# auditor must agree on the event vocabulary (schema-drift gate); (b) a
# sim serve run must emit a Perfetto trace whose offline replay conserves
# requests/tokens/blocks and whose TTFT/ITL percentiles match the exported
# serverStats bit-for-bit. Pure-stdlib python; the sim engine needs no
# artifacts or accelerator, so this lane always runs.
if python3 -c "import sys" >/dev/null 2>&1; then
    run python3 tools/event_sync_check.py
    TRACE_OUT=$(mktemp /tmp/loram_trace_XXXXXX.json)
    run cargo run --release -q -p loram -- serve --engine sim \
        --requests 24 --sim-mode spec --trace "$TRACE_OUT"
    run python3 tools/trace_report.py --check "$TRACE_OUT"
    rm -f "$TRACE_OUT" "${TRACE_OUT%.json}.jsonl"
    # the auditor's own unit tests are stdlib-only — run them even when
    # the jax-gated pytest lane below is skipped
    if python3 -c "import pytest" >/dev/null 2>&1; then
        (cd python && run python3 -m pytest -q tests/test_trace_report.py)
    fi
else
    echo "WARN: python3 not available; skipping trace audit lanes" >&2
fi

# L1/L2 python tests (model + AOT emitter contract) when a JAX env exists
if python3 -c "import jax, pytest" >/dev/null 2>&1; then
    PYTEST_ARGS=(-q tests)
    if ! python3 -c "import hypothesis" >/dev/null 2>&1; then
        echo "WARN: hypothesis not installed; skipping python/tests/test_kernels.py" >&2
        PYTEST_ARGS+=(--ignore=tests/test_kernels.py)
    fi
    # pytest must run from python/ so `compile` is importable
    (cd python && run python3 -m pytest "${PYTEST_ARGS[@]}")
    # §2f paged-equivalence lane, named explicitly so a collection change
    # (rename, accidental deselection) that hollows the dense-vs-paged
    # byte-identity contract out of the suite fails CI instead of
    # passing quietly; `-k paged` must select a non-empty set
    (cd python && run python3 -m pytest -q -k paged tests/test_model.py tests/test_aot.py)
    # meta-schema validation: every suite meta (and any emitted artifact
    # metas) must parse under runtime::meta's python mirror — adapter slot
    # groups and the decode_prefill_chunk window rule included, so a
    # misdeclared chunk artifact on disk fails CI here
    META_ARGS=()
    if [ -d artifacts ]; then
        META_ARGS=(--dir ../artifacts)
    fi
    # ${arr[@]+...} keeps `set -u` happy on bash < 4.4 when the array is empty
    (cd python && run python3 -m compile.meta_check ${META_ARGS[@]+"${META_ARGS[@]}"})
else
    echo "WARN: python3 with jax+pytest not available; skipping python/tests" >&2
fi

if cargo fmt --version >/dev/null 2>&1; then
    run cargo fmt --all --check
else
    echo "WARN: rustfmt not installed; skipping cargo fmt --check" >&2
fi

if cargo clippy --version >/dev/null 2>&1; then
    run cargo clippy --workspace --all-targets -- -D warnings
else
    echo "WARN: clippy not installed; skipping cargo clippy" >&2
fi

echo "ci.sh: all checks passed"

//! Vendored `xla` crate: the xla-rs API surface the LoRAM runtime uses.
//!
//! The real vendored build links the native `xla_extension` archive (PJRT
//! CPU plugin, patched with `ExecuteOptions::untuple_result=true` — see the
//! notes in `rust/src/runtime/`). That archive is not shipped in this
//! source tree, so this crate provides the same API as a *stub*: host-side
//! types ([`Literal`], [`ElementType`]) are fully functional, while every
//! entry point that needs the native runtime ([`PjRtClient::cpu`],
//! [`HloModuleProto::from_text_file`], execution, device transfers) returns
//! [`Error::Unavailable`]. Callers already degrade gracefully: the
//! coordinator reports the error, benches skip runtime sections, and the
//! pure-host test suite runs unaffected.
//!
//! Dropping the real `xla_rs` FFI implementation back in place keeps every
//! signature below unchanged.

use std::fmt;

const STUB: &str = "xla stub: native xla_extension runtime not present in this build \
                    (artifact execution requires the vendored PJRT plugin)";

#[derive(Debug, Clone)]
pub enum Error {
    /// The native runtime is not linked into this build.
    Unavailable(&'static str),
    /// Host-side usage error (shape/dtype mismatch, ...).
    Msg(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(m) => f.write_str(m),
            Error::Msg(m) => f.write_str(m),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error::Unavailable(STUB))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Host scalar types that map onto an [`ElementType`].
pub trait NativeType: Copy + 'static {
    const TY: ElementType;
    fn from_le(bytes: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le(bytes: [u8; 4]) -> Self {
        f32::from_le_bytes(bytes)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le(bytes: [u8; 4]) -> Self {
        i32::from_le_bytes(bytes)
    }
}

/// A host-resident tensor value (fully functional in the stub).
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = dims.iter().product();
        if data.len() != elems * 4 {
            return Err(Error::Msg(format!(
                "literal: {} data bytes != {elems} elements * 4",
                data.len()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), bytes: data.to_vec() })
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::TY != self.ty {
            return Err(Error::Msg(format!(
                "literal: requested {:?}, holds {:?}",
                T::TY,
                self.ty
            )));
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| T::from_le([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Decompose a tuple literal into its leaves (runtime-produced only).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }
}

pub struct PjRtDevice {
    _private: (),
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Literal-in / buffer-out execution.
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }

    /// Buffer-in / buffer-out execution (device-resident hot path).
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips_host_data() {
        let xs = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = xs.iter().flat_map(|x| x.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.dims(), &[3]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), xs);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn runtime_entry_points_report_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("xla stub"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }

    #[test]
    fn literal_shape_mismatch_is_an_error() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 4]).is_err()
        );
    }
}

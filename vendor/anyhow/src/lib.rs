//! Vendored minimal stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access, so this crate implements
//! exactly the subset the workspace uses: [`Result`], [`Error`],
//! [`Context`] (on both `Result` and `Option`), and the `anyhow!`, `bail!`
//! and `ensure!` macros. Error context is flattened into a single message
//! string eagerly (`"outer: inner: root"`), which matches how the
//! coordinator reports errors (`{e:#}` and `{e}` render identically here).

use std::fmt;

/// A flattened error message with its context chain pre-rendered.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { msg: msg.to_string() }
    }

    /// Wrap with an outer context frame.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

mod private {
    /// Sealed conversion used by [`super::Context`]: implemented for every
    /// std error type and for [`super::Error`] itself, so `.context()` can
    /// chain over both (the same coherence pattern the real anyhow uses).
    pub trait ErrLike {
        fn into_error(self) -> super::Error;
    }

    impl<E> ErrLike for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> super::Error {
            super::Error::from(self)
        }
    }

    impl ErrLike for super::Error {
        fn into_error(self) -> super::Error {
            self
        }
    }
}

pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: private::ErrLike> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(::std::format!($msg)) };
    ($err:expr $(,)?) => { $crate::Error::msg($err) };
    ($fmt:expr, $($arg:tt)*) => { $crate::Error::msg(::std::format!($fmt, $($arg)*)) };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => { return ::std::result::Result::Err($crate::anyhow!($($t)*)) };
}

/// Early-return with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            $crate::bail!($($t)*);
        }
    };
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"))
    }

    #[test]
    fn context_chains_on_result_and_option() {
        let e = io_err().context("reading file").unwrap_err();
        assert_eq!(e.to_string(), "reading file: boom");
        let e2: Result<()> = Err(e);
        let e2 = e2.with_context(|| "outer").unwrap_err();
        assert_eq!(e2.to_string(), "outer: reading file: boom");
        let n: Option<u32> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn macros_build_messages() {
        let a = anyhow!("x = {}", 3);
        assert_eq!(a.to_string(), "x = 3");
        let inline = 7;
        assert_eq!(anyhow!("v {inline}").to_string(), "v 7");
        fn f() -> Result<()> {
            bail!("nope {}", 1)
        }
        assert_eq!(f().unwrap_err().to_string(), "nope 1");
        fn g(ok: bool) -> Result<u8> {
            ensure!(ok, "not ok");
            Ok(1)
        }
        assert!(g(true).is_ok());
        assert_eq!(g(false).unwrap_err().to_string(), "not ok");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            io_err()?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "boom");
    }
}

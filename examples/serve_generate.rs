//! Serving example: batched generation through the L3 service loop
//! (request queue -> dynamic batcher -> logits artifact -> sampler).
//!
//!   cargo run --release --example serve_generate -- [n_requests]

use loram::coordinator::generate::{Generator, SampleCfg};
use loram::coordinator::pipeline::ensure_base;
use loram::data::instruct::{Dataset, InstructGen};
use loram::params::init_lora;
use loram::runtime::Runtime;
use loram::serve::Server;
use loram::util::stats;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);
    let rt = Runtime::new(loram::default_artifact_dir())?;
    std::fs::create_dir_all("runs")?;
    let params = ensure_base(&rt, "tiny", 60, 1e-3, 0, std::path::Path::new("runs"))?;
    let cfg = rt.load("eval_tiny")?.meta.config.clone();
    let lora = init_lora(&cfg, 0);
    let gen = Generator::new(&rt, "logits_tiny", &[&params, &lora])?;
    let mut server = Server::new(gen, 7);

    let mut ig = InstructGen::new(Dataset::Hermes, 3, 1);
    for _ in 0..n {
        let (ex, _) = ig.next();
        server.enqueue(
            ex.instruction,
            SampleCfg {
                temperature: 0.4,
                top_p: 0.95,
                max_new: 12,
            },
        );
    }
    let t0 = std::time::Instant::now();
    let responses = server.drain()?;
    let dt = t0.elapsed().as_secs_f64();
    let lats: Vec<f64> = responses.iter().map(|r| r.latency_ms).collect();
    for r in responses.iter().take(5) {
        println!("#{:<3} [{:>7.1} ms] {:?}", r.id, r.latency_ms, r.text);
    }
    println!(
        "\nserved {n} requests in {dt:.2}s — {:.2} req/s, latency p50 {:.0} ms p99 {:.0} ms, \
         {} batches (occupancy {:.0}%)",
        n as f64 / dt,
        stats::percentile(&lats, 50.0),
        stats::percentile(&lats, 99.0),
        server.stats.batches,
        100.0 * server.stats.total_batch_occupancy / server.stats.batches as f64
    );
    Ok(())
}

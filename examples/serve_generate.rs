//! Serving example: generation through the continuous-batching scheduler
//! (request queue -> free-row admission -> per-row sampling -> responses).
//!
//! Requests carry *their own* sampling configs and are admitted into batch
//! rows mid-decode: a latecomer enqueued while the first batch is still
//! decoding starts immediately in a freed row instead of waiting for the
//! whole batch to finish.
//!
//!   cargo run --release --example serve_generate -- [n_requests]

use loram::coordinator::generate::{Generator, SampleCfg};
use loram::coordinator::pipeline::ensure_base;
use loram::data::instruct::{Dataset, InstructGen};
use loram::params::init_lora;
use loram::runtime::Runtime;
use loram::serve::Server;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);
    let rt = Runtime::new(loram::default_artifact_dir())?;
    std::fs::create_dir_all("runs")?;
    let params = ensure_base(&rt, "tiny", 60, 1e-3, 0, std::path::Path::new("runs"))?;
    let cfg = rt.load("eval_tiny")?.meta.config.clone();
    let lora = init_lora(&cfg, 0);
    let gen = Generator::new(&rt, "logits_tiny", &[&params, &lora])?;
    let mut server = Server::new(gen, 7);

    let mut ig = InstructGen::new(Dataset::Hermes, 3, 1);
    for i in 0..n {
        let (ex, _) = ig.next();
        server.enqueue(
            ex.instruction,
            // per-request configs, mixed within a batch
            SampleCfg {
                temperature: if i % 2 == 0 { 0.4 } else { 0.0 },
                top_p: if i % 3 == 0 { 0.95 } else { 0.85 },
                max_new: 8 + 4 * (i % 2),
            },
        );
    }

    let t0 = std::time::Instant::now();
    // run a few scheduler ticks, then enqueue a latecomer mid-decode: it
    // is admitted into the next freed row, not after the current batch
    let mut responses = vec![];
    for _ in 0..3 {
        responses.extend(server.step()?);
    }
    let late = server.enqueue("What is 40 + 2?", SampleCfg::default());
    responses.extend(server.drain()?);
    let dt = t0.elapsed().as_secs_f64();

    for r in responses.iter().take(5) {
        println!(
            "#{:<3} [ttft {:>6.1} ms, total {:>7.1} ms, rows={}] {:?}",
            r.id, r.ttft_ms, r.latency_ms, r.batch_rows, r.text
        );
    }
    let late_pos = responses.iter().position(|r| r.id == late).unwrap_or(0);
    let st = &server.stats;
    println!(
        "\nserved {} requests in {dt:.2}s — {:.1} tok/s decode, mean ttft {:.0} ms, \
         p-lat {:.0} ms, {} decode steps, occupancy {:.0}%",
        st.served,
        st.tokens_per_sec(),
        st.mean_ttft_ms(),
        st.mean_latency_ms(),
        st.decode_steps,
        100.0 * st.mean_occupancy()
    );
    println!(
        "latecomer #{late} finished {} of {} (admitted mid-decode, no batch barrier)",
        late_pos + 1,
        st.served
    );
    Ok(())
}

//! Downstream-task example: reproduce one row-group of the paper's Table 1
//! style comparison on the tiny config — untrained base vs LoRA vs
//! LoRAM-Stru (recovered), across math / CSR / code.
//!
//!   cargo run --release --example downstream_eval

use loram::coordinator::downstream::{eval_all, ModelUnderTest};
use loram::coordinator::pipeline::{Pipeline, PipelineConfig, Variant};
use loram::data::instruct::Dataset;
use loram::params::init_lora;
use loram::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(loram::default_artifact_dir())?;
    std::fs::create_dir_all("runs")?;
    let mk = |variant, pruned: Option<&str>| PipelineConfig {
        base: "tiny".into(),
        pruned: pruned.map(String::from),
        variant,
        pretrain_steps: 60,
        align_steps: 12,
        sft_steps: 30,
        dataset: Dataset::Hermes,
        seed: 0,
        eval_every: 0,
        eval_seqs: 8,
        run_dir: "runs".into(),
        ..Default::default()
    };

    let loram = Pipeline::new(&rt, mk(Variant::Stru, Some("tiny_p50"))).run()?;
    let lora = Pipeline::new(&rt, mk(Variant::Lora, None)).run()?;
    let cfg = rt.load("eval_tiny")?.meta.config.clone();
    let zero = init_lora(&cfg, 0);

    println!(
        "{:<22} {:>7} {:>7} {:>9} {:>8} {:>8}",
        "method", "mathqa", "gsm", "csr_mean", "pass@1", "pass@10"
    );
    for (name, weights) in [
        ("tiny w/o FT", &zero),
        ("tiny LoRA", &lora.lora_recovered),
        ("tiny LoRAM-Stru", &loram.lora_recovered),
    ] {
        let m = ModelUnderTest::new(&rt, "tiny", &[&loram.base_params, weights])?;
        let s = eval_all(&m, 0, 12, 8, 4, 4, &[0.0, 0.4])?;
        println!(
            "{:<22} {:>7.3} {:>7.3} {:>9.3} {:>8.3} {:>8.3}",
            name, s.mathqa, s.gsm, s.csr_mean, s.pass1, s.pass10
        );
    }
    println!("\n(Full-scale version: `loram repro --exp tab1 --scale paper`.)");
    Ok(())
}

//! Quickstart: the complete LoRAM story on the tiny config in ~1 minute.
//!
//!   cargo run --release --example quickstart
//!
//! 1. pre-train a tiny LLaMA-style base model (the "published checkpoint")
//! 2. prune it (structured, gradient-importance), align, LoRA-SFT
//! 3. recover the low-rank factors and merge-evaluate on the FULL model
//! 4. compare against the plain-LoRA baseline and the untrained base

use loram::coordinator::evaluate::{test_sequences, Evaluator};
use loram::coordinator::pipeline::{Pipeline, PipelineConfig, Variant};
use loram::data::instruct::Dataset;
use loram::params::init_lora;
use loram::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(loram::default_artifact_dir())?;
    std::fs::create_dir_all("runs")?;

    println!("== LoRAM quickstart (tiny proxy config) ==");
    let mk = |variant, pruned: Option<&str>| PipelineConfig {
        base: "tiny".into(),
        pruned: pruned.map(String::from),
        variant,
        pretrain_steps: 60,
        align_steps: 12,
        sft_steps: 30,
        dataset: Dataset::Hermes,
        seed: 0,
        eval_every: 0,
        eval_seqs: 24,
        run_dir: "runs".into(),
        ..Default::default()
    };

    // LoRAM-Stru: train small (pruned), infer large (full)
    let loram = Pipeline::new(&rt, mk(Variant::Stru, Some("tiny_p50"))).run()?;
    // plain LoRA on the full model (upper baseline)
    let lora = Pipeline::new(&rt, mk(Variant::Lora, None)).run()?;

    let ood = test_sequences(Dataset::Alpaca, 0, 24);
    let full_cfg = rt.load("eval_tiny")?.meta.config.clone();
    let zero = init_lora(&full_cfg, 0);

    let ppl = |lora_w: &loram::tensor::TensorStore| -> anyhow::Result<f64> {
        Evaluator::new(&rt, "eval_tiny", &[&loram.base_params, lora_w])?
            .perplexity(&ood, true)
    };
    println!("\nout-of-domain perplexity (lower is better):");
    println!("  base w/o fine-tuning : {:8.3}", ppl(&zero)?);
    println!("  LoRAM-Stru recovered : {:8.3}", ppl(&loram.lora_recovered)?);
    println!("  plain LoRA (full)    : {:8.3}", ppl(&lora.lora_recovered)?);

    let pruned_cfg = rt.load("eval_tiny_p50")?.meta.config.clone();
    println!(
        "\ntrain-time base params: {} (LoRAM) vs {} (LoRA) => {:.2}x reduction",
        pruned_cfg.param_count(),
        full_cfg.param_count(),
        full_cfg.param_count() as f64 / pruned_cfg.param_count() as f64
    );
    println!("\nLoRAM trains on the small model but keeps (most of) the big");
    println!("model's inference quality — see `loram repro` for the full paper suite.");
    Ok(())
}

//! End-to-end validation driver (DESIGN.md: the "all layers compose" proof).
//!
//!   cargo run --release --example e2e_loram_pipeline -- [steps] [cfg]
//!
//! Trains the ~100M-parameter `e2e100m` transformer (L2 JAX model, lowered
//! to an HLO artifact, executed by the L3 Rust runtime) for `steps`
//! full-parameter steps on the synthetic corpus, logging the loss curve to
//! results/e2e/loss_curve.csv, then reports held-out perplexity
//! before/after. Defaults: 200 steps at ~100M params (see DESIGN.md
//! §E2E for the recorded run on this box).

use loram::coordinator::train::TrainSession;
use loram::data::{corpus::Corpus, make_batch};
use loram::params::{init_lora, init_params};
use loram::runtime::Runtime;
use loram::util::log::Csv;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(200);
    let cfg_name = args.get(1).cloned().unwrap_or_else(|| "e2e100m".to_string());
    let rt = Runtime::new(loram::default_artifact_dir())?;
    std::fs::create_dir_all("results/e2e")?;

    let art_name = format!("pretrain_{cfg_name}");
    let art = rt.load(&art_name)?;
    let cfg = art.meta.config.clone();
    println!(
        "e2e driver: {} — {} params, {} layers, d_model {}, batch {} x seq {}",
        cfg.name,
        cfg.param_count(),
        cfg.n_layers,
        cfg.d_model,
        art.meta.batch(),
        art.meta.seq()
    );

    let params = init_params(&cfg, 0);
    let mut sess = TrainSession::new(&rt, &art_name, &[&params])?;
    let (b, s) = (sess.batch_size(), sess.seq_len());
    let mut corpus = Corpus::new(0x9e37, 0.5);
    let mut csv = Csv::create("results/e2e/loss_curve.csv", &["step", "loss", "step_ms"])?;

    // held-out perplexity before training
    let eval_name = format!("eval_{cfg_name}");
    let eval_art = rt.load(&eval_name)?;
    let eval_s = eval_art.meta.seq();
    let mut held = Corpus::new(0xe7a1, 0.5);
    let held_seqs: Vec<Vec<i32>> = (0..32).map(|_| held.next_seq(eval_s - 1)).collect();
    let zero_lora = init_lora(&cfg, 0);
    let ppl_of = |p: &loram::tensor::TensorStore| -> anyhow::Result<f64> {
        loram::coordinator::evaluate::Evaluator::new(&rt, &eval_name, &[p, &zero_lora])?
            .perplexity(&held_seqs, false)
    };
    let ppl0 = ppl_of(&params)?;
    println!("held-out ppl before training: {ppl0:.3}");

    let t0 = Instant::now();
    for step in 0..steps {
        let seqs = corpus.next_seqs(b, s);
        let batch = make_batch(&seqs, b, s, false);
        let loss = sess.train_step(&batch, 3e-4)?;
        csv.row(&loram::csv_row![step, loss, format!("{:.1}", sess.step_ms[step])])?;
        if step % 10 == 0 || step + 1 == steps {
            println!(
                "step {step:>5}  loss {loss:.4}  ({:.2}s elapsed, {:.2}s/step)",
                t0.elapsed().as_secs_f64(),
                sess.mean_step_ms() / 1e3,
            );
        }
    }
    let pnames = sess.art.meta.name_list("param_names");
    let trained = sess.extract(&pnames)?;
    let ppl1 = ppl_of(&trained)?;
    println!(
        "\nheld-out ppl: {ppl0:.3} -> {ppl1:.3} after {steps} steps \
         ({:.1} min, mean {:.2}s/step, loss {:.4} -> {:.4})",
        t0.elapsed().as_secs_f64() / 60.0,
        sess.mean_step_ms() / 1e3,
        sess.losses.first().unwrap(),
        sess.losses.last().unwrap()
    );
    println!("loss curve -> results/e2e/loss_curve.csv");
    anyhow::ensure!(
        sess.losses.last().unwrap() < sess.losses.first().unwrap(),
        "loss did not decrease"
    );
    anyhow::ensure!(ppl1 < ppl0, "held-out perplexity did not improve");
    println!("E2E OK: L1 kernels -> L2 jax graph -> HLO artifact -> L3 rust loop all compose.");
    Ok(())
}

"""Chaos scheduler tick-model tests (stdlib only — no jax, no cargo).

Three layers, mirroring DESIGN.md Sec 2j:

1. `tools/chaos_gen.py` golden pins — the fault plans at (ticks=32,
   seed=9), the exact values `rust/src/chaos.rs` asserts in its unit
   tests, so the injected fault streams are bit-identical cross-language
   (same draw-for-draw contract as workload_gen vs workload.rs).
2. `tools/slo_sim.py` chaos pre-validation — the same fault scenarios
   the `serve.rs` ChaosEngine tests assert (row-fault isolation, retry
   budget exhaustion, byte-identical no-fault serving, device loss,
   degrade/recover, escalation-to-failing, the fault-storm A/B),
   checked against the Python tick model with the same expected numbers.
3. Conservation — every chaotic stream must pass the full
   `tools/trace_report.py` law suite (retry ledger, failure terminality,
   degradation bracketing included), --check and all, bit-for-bit.
"""

import json
import importlib.util
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[2]
sys.path.insert(0, str(REPO / "tools"))


def _load(name, rel):
    spec = importlib.util.spec_from_file_location(name, REPO / rel)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


wg = _load("workload_gen", "tools/workload_gen.py")
cg = _load("chaos_gen", "tools/chaos_gen.py")
sim = _load("slo_sim", "tools/slo_sim.py")
tr = _load("trace_report", "tools/trace_report.py")


def req(max_new, priority="normal", deadline=None):
    return {
        "arrival_tick": 0,
        "prompt_len": 1,
        "max_new": max_new,
        "priority": priority,
        "deadline_ticks": deadline,
        "adapter_ix": None,
    }


def planned(tick, kind_ix, row):
    return {"tick": tick, "kind_ix": kind_ix, "row": row}


def audit_ok(srv):
    """Full conservation suite over the model's stream: law replay plus
    the bit-for-bit --check against the embedded serverStats."""
    report = tr.audit(srv.events)
    assert report["violations"] == [], report["violations"]
    doc = srv.trace_doc()
    errs = tr.check(report, doc["serverStats"], doc["otherData"])
    assert errs == [], errs
    return report


# ----------------------------------------------- fault-plan golden pins


def test_fault_plans_match_the_rust_goldens():
    # pinned on the Rust side by
    # chaos.rs::plans_match_the_python_mirror_goldens (ticks=32, seed=9)
    def gold(s):
        plan = cg.generate(s, 32, 9)
        return len(plan), [(f["tick"], f["kind_ix"], f["row"]) for f in plan]

    n, first = gold("fault-storm")
    assert n == 14
    assert first[:4] == [(0, 0, 6), (2, 0, 2), (3, 2, 5), (4, 0, 5)]
    n, first = gold("decode-flaky")
    assert n == 9
    assert first[:4] == [(0, 0, 0), (3, 0, 1), (5, 0, 4), (8, 0, 5)]
    n, first = gold("admit-flaky")
    assert n == 12
    assert first[:4] == [(0, 1, 0), (1, 1, 0), (4, 1, 0), (5, 1, 0)]
    n, first = gold("pool-squeeze")
    assert n == 12
    assert first[:4] == [(0, 2, 0), (1, 2, 0), (4, 2, 0), (5, 2, 0)]
    assert gold("stuck-stall")[1] == [(1, 3, 0), (7, 3, 0), (17, 3, 0),
                                      (27, 3, 0)]
    assert gold("device-loss")[1] == [(5, 4, 0)]


def test_fault_plans_are_deterministic_tick_sorted_and_in_range():
    # mirror of chaos.rs::plans_are_deterministic_and_well_formed
    for s in cg.CHAOS_SCENARIOS:
        a = cg.generate(s, 64, 9)
        assert a == cg.generate(s, 64, 9), s
        last = -1
        for f in a:
            assert f["tick"] > last, f"{s}: plan must be tick-sorted, unique"
            last = f["tick"]
            assert 0 <= f["tick"] < 64
            assert 0 <= f["kind_ix"] < len(cg.FAULT_KINDS)
            assert 0 <= f["row"] < 8
    storm = cg.generate("fault-storm", 64, 9)
    assert all(f["kind_ix"] != 4 for f in storm), "storms must be survivable"


def test_unknown_chaos_scenario_raises_with_the_catalog():
    try:
        cg.generate("nope", 8, 0)
    except ValueError as e:
        assert "fault-storm" in str(e)
    else:
        raise AssertionError("unknown chaos scenario must raise")


def test_faults_workload_stream_matches_the_rust_goldens():
    # pinned on the Rust side by
    # workload.rs::generated_streams_match_the_python_mirror_goldens
    gold = [
        (r["arrival_tick"], r["prompt_len"], r["max_new"], r["priority"],
         r["deadline_ticks"], r["adapter_ix"])
        for r in wg.generate("faults", 4, 9)
    ]
    assert gold == [
        (1, 15, 8, "normal", None, None),
        (3, 6, 6, "normal", None, None),
        (4, 14, 6, "normal", None, None),
        (4, 14, 3, "normal", None, None),
    ]
    # mirror of workload.rs::faults_scenario_has_a_deadline_slice…
    rs = wg.generate("faults", 64, 9)
    hi = [r for r in rs if r["priority"] == "high"]
    assert len(hi) == 6 and all(r["deadline_ticks"] is not None for r in hi)
    assert not any(r["priority"] == "low" for r in rs)
    assert rs[-1]["arrival_tick"] == 66, "arrivals must be paced, not a wall"


# ---------------------------------------- chaos scenario pre-checks (§2j)


def test_row_fault_is_retried_and_isolated_from_the_batch():
    # mirror of serve.rs::row_fault_is_retried_and_isolated_from_the_batch:
    # one transient fault on row 0; the other row never notices, the
    # victim re-runs to completion, nothing is lost
    srv = sim.SimServer(2, chaos=[planned(1, 0, 0)], retry_budget=2)
    a = srv.enqueue(req(4))
    b = srv.enqueue(req(4))
    done = srv.drain()
    assert {d["id"] for d in done} == {a, b}
    assert all(d["tokens"] == 4 for d in done if not d.get("failed"))
    assert (srv.retries, srv.preempted, srv.failed) == (1, 1, 0)
    assert srv.injected == 1 and srv.health == "healthy"
    rep = audit_ok(srv)
    assert (rep["faults"], rep["retries"], rep["failed"]) == (1, 1, 0)
    assert rep["preempted_tokens"] == 1


def test_retry_budget_exhaustion_fails_terminally_with_first_class_outcome():
    # mirror of serve.rs::retry_budget_exhaustion_fails_terminally…: two
    # faults against a budget of one — the second is terminal, the
    # failure is a first-class outcome, and goodput counts it
    srv = sim.SimServer(1, chaos=[planned(1, 0, 0), planned(4, 0, 0)],
                        retry_budget=1)
    rid = srv.enqueue(req(4))
    done = srv.drain()
    assert [d["id"] for d in done] == [rid]
    assert done[0]["failed"] and done[0]["tokens"] == 0
    assert (srv.retries, srv.failed, srv.served) == (1, 1, 0)
    assert srv.goodput() == 0.0
    rep = audit_ok(srv)
    assert (rep["faults"], rep["retries"], rep["failed"]) == (2, 1, 1)
    assert rep["preempted_tokens"] == 1 and rep["failed_tokens"] == 1


def test_chaos_off_retry_policy_is_byte_identical_to_plain_serving():
    # mirror of serve.rs::chaos_off_retry_policy_is_byte_identical…: an
    # empty fault plan plus an armed retry policy must not perturb a
    # single event — the machinery is strictly opt-in
    def drive(srv):
        for i in range(6):
            srv.enqueue(req(2 + i % 3, "high" if i % 3 == 0 else "normal"))
            srv.step()
        return srv.drain()

    plain = sim.SimServer(2, slo=True)
    chaotic = sim.SimServer(2, slo=True, chaos=[], retry_budget=3,
                            backoff_base=2)
    assert drive(plain) == drive(chaotic)
    assert plain.events == chaotic.events
    assert chaotic.injected == 0 and chaotic.retries == 0
    assert plain.server_stats() == chaotic.server_stats()


def test_device_loss_fails_everything_loudly_and_terminally():
    # mirror of serve.rs::device_loss_fails_everything_loudly…: loss
    # drains every survivor as Failed, and late arrivals fail too
    srv = sim.SimServer(2, chaos=[planned(2, 4, 0)], retry_budget=2)
    ids = [srv.enqueue(req(8)) for _ in range(3)]
    done = srv.drain()
    assert [d["id"] for d in done if d.get("failed")] and len(done) == 3
    assert {d["id"] for d in done} == set(ids)
    assert all(d.get("failed") for d in done)
    assert srv.health == "failing" and srv.failed == 3
    late = srv.enqueue(req(2))
    out = srv.step()
    assert [d["id"] for d in out] == [late] and out[0]["failed"]
    rep = audit_ok(srv)
    assert rep["failed"] == 4 and rep["degrades"] == 1


def test_stuck_tick_degrades_and_clean_ticks_recover():
    # mirror of serve.rs::stuck_tick_degrades_and_clean_ticks_recover: an
    # engine-domain fault opens a degraded bracket; three clean decode
    # ticks close it with Recover and serving never stops
    srv = sim.SimServer(2, chaos=[planned(1, 3, 0)], retry_budget=2)
    srv.enqueue(req(5))
    srv.enqueue(req(5))
    done = srv.drain()
    assert len(done) == 2 and not any(d.get("failed") for d in done)
    assert srv.health == "healthy" and srv.degraded_ticks == 3
    rep = audit_ok(srv)
    assert rep["degrades"] == 1
    brackets = [e["kind"] for e in srv.events
                if e["kind"] in ("Degrade", "Recover")]
    assert brackets == ["Degrade", "Recover"]


def test_three_consecutive_engine_faults_escalate_to_failing():
    # mirror of serve.rs::three_consecutive_engine_faults_escalate…
    plan = [planned(1, 3, 0), planned(2, 3, 0), planned(3, 3, 0)]
    srv = sim.SimServer(1, chaos=plan, retry_budget=2)
    rid = srv.enqueue(req(8))
    done = srv.drain()
    assert [d["id"] for d in done] == [rid] and done[0]["failed"]
    assert srv.health == "failing" and srv.failed == 1
    rep = audit_ok(srv)
    assert rep["degrades"] == 2  # degraded, then the failing escalation


def test_fault_storm_with_retry_isolation_loses_nothing_silently():
    # the BENCH_serve fault-storm headline, pre-validated in the model:
    # every offered request resolves as served/failed/cancelled/rejected
    retry, abort, err = sim.run_chaos_ab("faults", 24, 9, 4,
                                         "fault-storm", 64)
    assert err is not None, "abort-on-error must die in the storm"
    assert retry.injected > 0 and retry.served > 0
    resolved = retry.served + retry.failed + retry.cancelled + retry.rejected
    assert resolved == 24, "no request may vanish silently"
    assert sim.goodput_offered(retry, 24) > sim.goodput_offered(abort, 24)
    rep = audit_ok(retry)
    assert rep["retries"] == retry.retries
    assert rep["failed"] == retry.failed


def test_every_chaos_scenario_stream_passes_conservation():
    # widened mirror of serve.rs's per-scenario chaos tests: every fault
    # plan, replayed over the faults workload, must satisfy the whole law
    # suite — retry ledger, terminality and bracketing included
    reqs = wg.generate("faults", 16, 3)
    for scn in cg.CHAOS_SCENARIOS:
        srv = sim.SimServer(4, chaos=cg.generate(scn, 64, 3),
                            retry_budget=2)
        done = sim.run_workload(srv, reqs)
        rep = audit_ok(srv)
        resolved = srv.served + srv.failed + srv.cancelled + srv.rejected
        assert resolved == 16, f"{scn}: lost a request silently"
        assert len(done) + srv.cancelled + srv.rejected == 16, scn
        assert rep["faults"] >= rep["retries"], scn


def test_chaos_ab_cli_gate_exits_zero_on_the_headline_scenario(capsys):
    rc = sim.main(["slo_sim.py", "--chaos-ab", "faults", "-n", "24",
                   "--seed", "9", "--batch", "4"])
    out = capsys.readouterr().out
    assert rc == 0, out
    assert "retry+isolation beats abort-on-error" in out


def test_chaotic_trace_doc_roundtrips_through_trace_report_check(tmp_path):
    srv = sim.SimServer(4, chaos=cg.generate("fault-storm", 64, 9),
                        retry_budget=2)
    sim.run_workload(srv, wg.generate("faults", 24, 9))
    path = tmp_path / "chaos.json"
    path.write_text(json.dumps(srv.trace_doc()))
    assert tr.main(["trace_report.py", "--check", str(path)]) == 0

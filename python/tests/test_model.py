"""L2 model correctness: shapes, losses, training dynamics, LoRAM semantics.

These tests exercise the exact functions that aot.py lowers, so passing here
means the artifacts compute the right thing (the Rust integration tests then
check the PJRT round-trip itself).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.configs import PRESETS, ModelConfig, pruned_config
from compile.kernels import ref as kref

CFG = PRESETS["tiny"]


def _params(cfg, seed=0):
    return M.init_params(cfg, jax.random.PRNGKey(seed))


def _lora(cfg, seed=1):
    return M.init_lora(cfg, jax.random.PRNGKey(seed))


def _tokens(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)


# ---------------------------------------------------------------------------
# shapes & config plumbing
# ---------------------------------------------------------------------------

def test_param_count_matches_shapes():
    for name, cfg in PRESETS.items():
        total = sum(int(np.prod(s)) for s in M.param_shapes(cfg).values())
        assert total == cfg.param_count(), name


def test_pruned_config_shrinks_params():
    cfg = PRESETS["l13b"]
    p = pruned_config(cfg, 0.65)
    assert p.param_count() < cfg.param_count()
    # protected layers keep full shapes
    assert p.layer_shapes(0) == (cfg.n_heads, cfg.n_kv_heads, cfg.d_ff)
    assert p.layer_shapes(cfg.n_layers - 1) == \
        (cfg.n_heads, cfg.n_kv_heads, cfg.d_ff)
    # middle layers are pruned
    mid = cfg.n_layers // 2
    h, kv, ff = p.layer_shapes(mid)
    assert h < cfg.n_heads and ff < cfg.d_ff


def test_reduction_ratio_monotone_in_pruning_ratio():
    cfg = PRESETS["l70b"]
    counts = [pruned_config(cfg, r).param_count()
              for r in (0.65, 0.75, 0.85, 0.95)]
    assert counts == sorted(counts, reverse=True)


def test_forward_shapes():
    params = _params(CFG)
    proj = M.ProjCtx(params, cfg=CFG)
    toks = _tokens(CFG, 2, 16)
    logits = M.forward(CFG, proj, toks)
    assert logits.shape == (2, 16, CFG.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_forward_pruned_config_shapes():
    cfg = pruned_config(CFG, 0.5)
    params = _params(cfg)
    proj = M.ProjCtx(params, cfg=cfg)
    logits = M.forward(cfg, proj, _tokens(cfg, 2, 16))
    assert logits.shape == (2, 16, cfg.vocab_size)


def test_causality():
    """Changing a future token must not change past logits."""
    params = _params(CFG)
    proj = M.ProjCtx(params, cfg=CFG)
    t1 = _tokens(CFG, 1, 16, seed=0)
    t2 = t1.at[0, -1].set((t1[0, -1] + 1) % CFG.vocab_size)
    l1 = M.forward(CFG, proj, t1)
    l2 = M.forward(CFG, proj, t2)
    np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], rtol=1e-5, atol=1e-5)
    assert not np.allclose(l1[0, -1], l2[0, -1])


# ---------------------------------------------------------------------------
# LoRA semantics
# ---------------------------------------------------------------------------

def test_fresh_lora_is_identity():
    """b initialises to zero, so fresh LoRA must not change the forward."""
    params = _params(CFG)
    lora = _lora(CFG)
    toks = _tokens(CFG, 2, 16)
    base = M.forward(CFG, M.ProjCtx(params, cfg=CFG), toks)
    with_lora = M.forward(CFG, M.ProjCtx(params, lora=lora, cfg=CFG), toks)
    np.testing.assert_allclose(base, with_lora, rtol=1e-6, atol=1e-6)


def test_lora_merge_equivalence():
    """x@(W + s·a·b) == fused LoRA path — the recovery/merge identity (Eq. 7)."""
    cfg = CFG
    params = _params(cfg)
    lora = _lora(cfg)
    # give b real values
    lora = {k: (v if k.endswith("lora_a")
                else jax.random.normal(jax.random.PRNGKey(9), v.shape) * 0.05)
            for k, v in lora.items()}
    toks = _tokens(cfg, 2, 16)
    fused = M.forward(cfg, M.ProjCtx(params, lora=lora, cfg=cfg), toks)
    merged = dict(params)
    scale = cfg.lora_alpha / cfg.lora_rank
    for i in range(cfg.n_layers):
        for k in M.LAYER_PROJ:
            nm = f"l{i}.{k}"
            merged[nm] = params[nm] + scale * (
                lora[f"{nm}.lora_a"] @ lora[f"{nm}.lora_b"])
    merged["lm_head"] = params["lm_head"] + scale * (
        lora["lm_head.lora_a"] @ lora["lm_head.lora_b"])
    plain = M.forward(cfg, M.ProjCtx(merged, cfg=cfg), toks)
    np.testing.assert_allclose(fused, plain, rtol=2e-4, atol=2e-4)


def test_masked_lora_blocks_pruned_positions():
    """C2: gradients w.r.t. a/b only flow through unpruned positions —
    equivalently, the masked forward ignores updates at masked entries."""
    cfg = CFG
    params = _params(cfg)
    lora = _lora(cfg)
    rng = np.random.default_rng(0)
    masks, mparams = {}, dict(params)
    for i in range(cfg.n_layers):
        for k in M.LAYER_PROJ:
            nm = f"l{i}.{k}"
            m = jnp.asarray(rng.integers(0, 2, params[nm].shape), jnp.float32)
            masks[f"{nm}.mask"] = m
            mparams[nm] = params[nm] * m
    toks = _tokens(cfg, 2, 16)

    def loss(lr):
        proj = M.ProjCtx(mparams, lora=lr, masks=masks, cfg=cfg)
        logits = M.forward(cfg, proj, toks[:, :-1])
        return M.mean_loss(logits, toks[:, 1:],
                           jnp.ones((2, 15), jnp.float32))

    grads = jax.grad(loss)(lora)
    # gradient w.r.t. a for a fully-masked projection must be zero
    nm = "l0.wq"
    zmask = {**masks, f"{nm}.mask": jnp.zeros_like(masks[f"{nm}.mask"])}

    def loss0(lr):
        proj = M.ProjCtx(mparams, lora=lr, masks=zmask, cfg=cfg)
        logits = M.forward(cfg, proj, toks[:, :-1])
        return M.mean_loss(logits, toks[:, 1:],
                           jnp.ones((2, 15), jnp.float32))

    g0 = jax.grad(loss0)(lora)
    assert float(jnp.abs(g0[f"{nm}.lora_a"]).max()) == 0.0
    assert float(jnp.abs(g0[f"{nm}.lora_b"]).max()) == 0.0
    # ... but is generally nonzero under a random mask (b: fresh LoRA has
    # b = 0, so only b receives gradient on the first step)
    assert float(jnp.abs(grads[f"{nm}.lora_b"]).max()) > 0.0


# ---------------------------------------------------------------------------
# losses & optimiser
# ---------------------------------------------------------------------------

def test_token_nll_matches_manual():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(2, 4, 8)), jnp.float32)
    targets = jnp.asarray(rng.integers(0, 8, (2, 4)), jnp.int32)
    mask = jnp.ones((2, 4), jnp.float32)
    s, c = M.token_nll(logits, targets, mask)
    logp = np.log(np.exp(logits) / np.exp(logits).sum(-1, keepdims=True))
    want = -np.take_along_axis(logp, np.asarray(targets)[..., None],
                               axis=-1)[..., 0].sum(-1)
    np.testing.assert_allclose(s, want, rtol=1e-5)
    np.testing.assert_allclose(c, [4.0, 4.0])


def test_loss_mask_excludes_tokens():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(1, 4, 8)), jnp.float32)
    targets = jnp.asarray(rng.integers(0, 8, (1, 4)), jnp.int32)
    m1 = jnp.asarray([[1, 1, 0, 0]], jnp.float32)
    s1, c1 = M.token_nll(logits, targets, m1)
    sfull, _ = M.token_nll(logits, targets, jnp.ones((1, 4), jnp.float32))
    assert float(s1[0]) < float(sfull[0])
    assert float(c1[0]) == 2.0


def test_adam_decreases_loss_pretrain():
    """A few full-param steps on a fixed batch must reduce the loss."""
    cfg = CFG
    fn, pnames, _ = M.make_pretrain_step(cfg)
    params = _params(cfg)
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(v_) for k, v_ in params.items()}
    toks = _tokens(cfg, 2, 17)
    mask = jnp.ones((2, 16), jnp.float32)
    losses = []
    for step in range(1, 6):
        out = fn(jnp.float32(step), jnp.float32(1e-2), toks, mask,
                 *[params[k] for k in pnames], *[m[k] for k in pnames],
                 *[v[k] for k in pnames])
        losses.append(float(out[0]))
        n = len(pnames)
        params = dict(zip(pnames, out[1:1 + n]))
        m = dict(zip(pnames, out[1 + n:1 + 2 * n]))
        v = dict(zip(pnames, out[1 + 2 * n:1 + 3 * n]))
    assert losses[-1] < losses[0]


def test_sft_step_only_updates_lora():
    cfg = CFG
    fn, pnames, qn, mn, lnames = M.make_sft_step(cfg)
    params = _params(cfg)
    lora = _lora(cfg)
    m = {k: jnp.zeros_like(t) for k, t in lora.items()}
    v = {k: jnp.zeros_like(t) for k, t in lora.items()}
    toks = _tokens(cfg, 2, 17)
    mask = jnp.ones((2, 16), jnp.float32)
    out = fn(jnp.float32(1), jnp.float32(1e-3), toks, mask,
             *[params[k] for k in pnames], *[lora[k] for k in lnames],
             *[m[k] for k in lnames], *[v[k] for k in lnames])
    loss = float(out[0])
    assert np.isfinite(loss)
    new_lora = dict(zip(lnames, out[1:1 + len(lnames)]))
    nl = len(lnames)
    new_m = dict(zip(lnames, out[1 + nl:1 + 2 * nl]))
    new_v = dict(zip(lnames, out[1 + 2 * nl:1 + 3 * nl]))
    # step 1 with fresh LoRA (b = 0): every b changes; a has zero gradient
    for k in lnames:
        delta = float(jnp.abs(new_lora[k] - lora[k]).max())
        if k.endswith("lora_b"):
            assert delta > 0, k
        else:
            assert delta == 0, k
    # step 2: a receives gradient through the now-nonzero b
    out2 = fn(jnp.float32(2), jnp.float32(1e-3), toks, mask,
              *[params[k] for k in pnames],
              *[new_lora[k] for k in lnames],
              *[new_m[k] for k in lnames], *[new_v[k] for k in lnames])
    lora2 = dict(zip(lnames, out2[1:1 + nl]))
    changed = sum(float(jnp.abs(lora2[k] - new_lora[k]).max()) > 0
                  for k in lnames)
    assert changed == nl


def test_quantized_sft_close_to_dense():
    """NF4-based SFT loss must approximate the f32 loss (paper Eq. 9)."""
    cfg = CFG
    from compile.aot import NF4_BLOCK
    fn_q, pnames_q, qnames, _, lnames = M.make_sft_step(cfg, quantized=True)
    fn_d, pnames_d, _, _, _ = M.make_sft_step(cfg)
    params = _params(cfg)
    lora = _lora(cfg)
    m = {k: jnp.zeros_like(t) for k, t in lora.items()}
    v = {k: jnp.zeros_like(t) for k, t in lora.items()}
    quant = {}
    for i in range(cfg.n_layers):
        for k in M.QUANT_PROJ:
            nm = f"l{i}.{k}"
            codes, absmax = kref.nf4_quantize_ref(params[nm], NF4_BLOCK)
            quant[f"{nm}.codes"] = codes
            quant[f"{nm}.absmax"] = absmax
    toks = _tokens(cfg, 2, 17)
    mask = jnp.ones((2, 16), jnp.float32)
    common = (jnp.float32(1), jnp.float32(1e-3), toks, mask)
    out_q = fn_q(*common, *[params[k] for k in pnames_q],
                 *[quant[k] for k in qnames], *[lora[k] for k in lnames],
                 *[m[k] for k in lnames], *[v[k] for k in lnames])
    out_d = fn_d(*common, *[params[k] for k in pnames_d],
                 *[lora[k] for k in lnames], *[m[k] for k in lnames],
                 *[v[k] for k in lnames])
    assert abs(float(out_q[0]) - float(out_d[0])) < 0.5


def test_grad_importance_shapes_and_positivity():
    cfg = CFG
    fn, pnames = M.make_grad_importance(cfg)
    params = _params(cfg)
    toks = _tokens(cfg, 2, 17)
    mask = jnp.ones((2, 16), jnp.float32)
    head_imp, ff_imp = fn(toks, mask, *[params[k] for k in pnames])
    assert head_imp.shape == (cfg.n_layers, cfg.n_heads)
    assert ff_imp.shape == (cfg.n_layers, cfg.d_ff)
    assert float(head_imp.min()) >= 0.0 and float(ff_imp.min()) >= 0.0
    assert float(head_imp.max()) > 0.0


# ---------------------------------------------------------------------------
# KV-cache decode path
# ---------------------------------------------------------------------------

def _nonzero_lora(cfg, seed=7):
    lora = M.init_lora(cfg, jax.random.PRNGKey(1))
    return {k: (v if k.endswith("lora_a")
                else jax.random.normal(jax.random.PRNGKey(seed), v.shape) * 0.05)
            for k, v in lora.items()}


def _assert_kv_greedy_matches_reforward(cfg, prompts, steps, s):
    """Drive prefill+step over zero caches and check every step's logits —
    and the greedy token stream — against a full reforward of the same
    sequences. This is the contract the Rust KV decode path relies on."""
    b = len(prompts)
    params = _params(cfg)
    lora = _nonzero_lora(cfg)
    pfn, pn, ln, cn = M.make_decode_prefill(cfg)
    sfn, *_ = M.make_decode_step(cfg)
    shapes = M.kv_cache_shapes(cfg, b, s)
    caches = {n: jnp.zeros(shapes[n], jnp.float32) for n in cn}
    flat = [params[k] for k in pn] + [lora[k] for k in ln]
    for row, p in enumerate(prompts):
        toks = jnp.asarray([list(p) + [0] * (s - len(p))], jnp.int32)
        oh = jnp.zeros((b,), jnp.float32).at[row].set(1.0)
        out = pfn(toks, jnp.int32(len(p) - 1), oh,
                  *flat, *[caches[n] for n in cn])
        caches = dict(zip(cn, out[1:]))
    proj = M.ProjCtx(params, lora=lora, cfg=cfg)
    seqs = [list(p) for p in prompts]
    for _ in range(steps):
        toks = jnp.asarray([[seq[-1]] for seq in seqs], jnp.int32)
        pos = jnp.asarray([len(seq) - 1 for seq in seqs], jnp.int32)
        out = sfn(toks, pos, *flat, *[caches[n] for n in cn])
        caches = dict(zip(cn, out[1:]))
        grid = jnp.asarray([seq + [0] * (s - len(seq)) for seq in seqs],
                           jnp.int32)
        ref = M.forward(cfg, proj, grid)
        for r, seq in enumerate(seqs):
            ref_row = ref[r, len(seq) - 1]
            np.testing.assert_allclose(out[0][r], ref_row,
                                       rtol=2e-3, atol=2e-3)
            assert int(jnp.argmax(out[0][r])) == int(jnp.argmax(ref_row))
            seq.append(int(jnp.argmax(ref_row)))


def test_decode_cache_matches_full_reforward_greedy():
    _assert_kv_greedy_matches_reforward(
        CFG, prompts=[[1, 2, 3, 4, 5], [9, 8, 7]], steps=6, s=24)


def test_decode_cache_matches_reforward_gqa_and_pruned_plan():
    """GQA (kv < h, dividing) and a pruned layer plan whose head counts do
    not divide (tile+trim) must both round-trip through the cache."""
    gqa = ModelConfig(name="gqa4", d_model=64, n_layers=2, n_heads=4,
                      n_kv_heads=2, d_ff=96, max_seq=32)
    _assert_kv_greedy_matches_reforward(
        gqa, prompts=[[5, 6, 7], [11, 12, 13, 14]], steps=4, s=16)
    pruned = ModelConfig(name="pp", d_model=64, n_layers=2, n_heads=4,
                         n_kv_heads=2, d_ff=96, max_seq=32,
                         layer_plan=[[4, 2, 96], [3, 2, 64]])
    _assert_kv_greedy_matches_reforward(
        pruned, prompts=[[3, 1, 4, 1], [2, 7]], steps=4, s=16)


def test_decode_prefill_only_touches_selected_row():
    """Admitting into one row must leave every other row's cache bitwise
    intact (mid-decode admission safety)."""
    cfg = CFG
    b, s = 3, 16
    params = _params(cfg)
    lora = _nonzero_lora(cfg)
    pfn, pn, ln, cn = M.make_decode_prefill(cfg)
    shapes = M.kv_cache_shapes(cfg, b, s)
    rng = np.random.default_rng(0)
    caches = {n: jnp.asarray(rng.normal(size=shapes[n]), jnp.float32)
              for n in cn}
    flat = [params[k] for k in pn] + [lora[k] for k in ln]
    toks = jnp.asarray([[1, 2, 3] + [0] * (s - 3)], jnp.int32)
    oh = jnp.zeros((b,), jnp.float32).at[1].set(1.0)
    out = pfn(toks, jnp.int32(2), oh, *flat, *[caches[n] for n in cn])
    new_caches = dict(zip(cn, out[1:]))
    for n in cn:
        before, after = np.asarray(caches[n]), np.asarray(new_caches[n])
        np.testing.assert_array_equal(before[0], after[0])
        np.testing.assert_array_equal(before[2], after[2])
        assert not np.array_equal(before[1], after[1])
    assert out[0].shape == (1, cfg.vocab_size)


# ---------------------------------------------------------------------------
# Chunked prefill: the (1, C) admission window (DESIGN.md §2e)
# ---------------------------------------------------------------------------

def _chunk_admit(cfg, chunk_fn, flat, cn, caches, row, prompt, b, ladder,
                 adapter_ix=None):
    """Admit one prompt through the bucket ladder — the python mirror of
    kvcache::chunk_plan: a covering bucket only when its padding beats
    the smallest bucket, else full windows of the largest bucket that
    fits the remainder. Returns (caches, final-chunk logits)."""
    start, logits = 0, None
    oh = jnp.zeros((b,), jnp.float32).at[row].set(1.0)
    while start < len(prompt):
        remaining = len(prompt) - start
        fit = next((c for c in ladder if c >= remaining), None)
        if fit is not None and fit - remaining >= ladder[0]:
            fit = None  # covering pad beats the ladder: split instead
        bucket = fit if fit is not None else max(
            (c for c in ladder if c <= remaining), default=ladder[-1])
        take = min(bucket, remaining)
        window = list(prompt[start:start + take]) + [0] * (bucket - take)
        args = [jnp.asarray([window], jnp.int32), jnp.int32(start),
                jnp.int32(take - 1), oh]
        if adapter_ix is not None:
            args.append(jnp.int32(adapter_ix))
        out = chunk_fn(*args, *flat, *[caches[n] for n in cn])
        caches = dict(zip(cn, out[1:]))
        logits = out[0]
        start += take
    return caches, logits


def _assert_chunked_matches_monolithic(cfg, prompts, s, ladder, steps=4):
    """The §2e acceptance contract: admission through (1, C) windows must
    leave the same prompt-position K/V, the same last-token logits, and
    the same greedy continuation stream as the monolithic (1, S) prefill."""
    b = len(prompts)
    params = _params(cfg)
    lora = _nonzero_lora(cfg)
    pn, ln, cn = (M.param_names(cfg), M.lora_names(cfg), M.kv_cache_names(cfg))
    flat = [params[k] for k in pn] + [lora[k] for k in ln]
    mono = _prefill_caches(cfg, flat, cn, prompts, b, s)
    cfn, *_ = M.make_decode_prefill_chunk(cfg)
    shapes = M.kv_cache_shapes(cfg, b, s)
    chunked = {n: jnp.zeros(shapes[n], jnp.float32) for n in cn}
    proj = M.ProjCtx(params, lora=lora, cfg=cfg)
    for row, p in enumerate(prompts):
        chunked, logits = _chunk_admit(cfg, cfn, flat, cn, chunked, row, p,
                                       b, ladder)
        # final-chunk logits == the full forward at the prompt's last token
        grid = jnp.asarray([list(p) + [0] * (s - len(p))], jnp.int32)
        ref = M.forward(cfg, proj, grid)[0, len(p) - 1]
        np.testing.assert_allclose(logits[0], ref, rtol=2e-3, atol=2e-3)
        assert int(jnp.argmax(logits[0])) == int(jnp.argmax(ref))
    # prompt-position K/V identical to the monolithic prefill's (positions
    # beyond the prompt are garbage on both paths and masked by position)
    for n in cn:
        for row, p in enumerate(prompts):
            np.testing.assert_allclose(
                np.asarray(chunked[n])[row, :len(p)],
                np.asarray(mono[n])[row, :len(p)], rtol=2e-3, atol=2e-3)
    # greedy continuation: both cache sets step to identical streams
    sfn, *_ = M.make_decode_step(cfg)
    seqs = {"mono": [list(p) for p in prompts],
            "chunk": [list(p) for p in prompts]}
    caches = {"mono": mono, "chunk": chunked}
    streams = {k: [[] for _ in prompts] for k in seqs}
    for _ in range(steps):
        for kind in ("mono", "chunk"):
            sq = seqs[kind]
            toks = jnp.asarray([[q[-1]] for q in sq], jnp.int32)
            pos = jnp.asarray([len(q) - 1 for q in sq], jnp.int32)
            out = sfn(toks, pos, *flat, *[caches[kind][n] for n in cn])
            caches[kind] = dict(zip(cn, out[1:]))
            for r, q in enumerate(sq):
                if len(q) >= s:
                    continue  # a full-grid prompt has no generation room
                t = int(jnp.argmax(out[0][r]))
                streams[kind][r].append(t)
                q.append(t)
    assert streams["mono"] == streams["chunk"], \
        f"chunked admission diverged: {streams}"


def test_chunked_prefill_matches_monolithic_across_bucket_shapes():
    """Prompt < one chunk, an exact bucket multiple, a bucket+remainder
    split, and an S-length prompt all admit identically to pad-to-S."""
    s = 24
    # single-bucket ladder forces genuine multi-chunk admissions
    _assert_chunked_matches_monolithic(
        CFG, prompts=[[1, 2, 3], [5, 6, 7, 8, 9, 10, 11, 12],
                      [9, 8, 7, 6, 5, 4, 3, 2, 1, 9, 8]],
        s=s, ladder=[8])
    # ladder with the full grid: short prompts take the small bucket, the
    # S-length prompt takes the full grid in one window, and a prompt
    # whose covering bucket would pad >= ladder[0] splits into full
    # windows instead (the low-padding rule)
    _assert_chunked_matches_monolithic(
        CFG, prompts=[[2, 4, 6], list(range(1, 11)), list(range(1, s + 1))],
        s=s, ladder=[8, s], steps=3)


def test_chunked_prefill_gqa_and_pruned_plan():
    """GQA (kv < h) and a pruned layer plan with non-dividing head counts
    must round-trip through the chunk window too."""
    gqa = ModelConfig(name="gqa4", d_model=64, n_layers=2, n_heads=4,
                      n_kv_heads=2, d_ff=96, max_seq=32)
    _assert_chunked_matches_monolithic(
        gqa, prompts=[[5, 6, 7], [11, 12, 13, 14, 15, 16, 17, 18, 19]],
        s=16, ladder=[8])
    pruned = ModelConfig(name="pp", d_model=64, n_layers=2, n_heads=4,
                         n_kv_heads=2, d_ff=96, max_seq=32,
                         layer_plan=[[4, 2, 96], [3, 2, 64]])
    _assert_chunked_matches_monolithic(
        pruned, prompts=[[3, 1, 4, 1], [2, 7, 1, 8, 2, 8, 1, 8, 2]],
        s=16, ladder=[8])


def test_chunked_prefill_only_touches_selected_row_and_window():
    """A chunk write must leave every other row bitwise intact AND every
    untouched slot of the selected row intact — mid-decode admission and
    mid-admission decode are both safe."""
    cfg = CFG
    b, s = 3, 16
    params = _params(cfg)
    lora = _nonzero_lora(cfg)
    pn, ln, cn = (M.param_names(cfg), M.lora_names(cfg), M.kv_cache_names(cfg))
    flat = [params[k] for k in pn] + [lora[k] for k in ln]
    cfn, *_ = M.make_decode_prefill_chunk(cfg)
    shapes = M.kv_cache_shapes(cfg, b, s)
    rng = np.random.default_rng(0)
    caches = {n: jnp.asarray(rng.normal(size=shapes[n]), jnp.float32)
              for n in cn}
    # window of 4 real tokens at start 8 in row 1
    window = [1, 2, 3, 4]
    oh = jnp.zeros((b,), jnp.float32).at[1].set(1.0)
    out = cfn(jnp.asarray([window], jnp.int32), jnp.int32(8), jnp.int32(3),
              oh, *flat, *[caches[n] for n in cn])
    new = dict(zip(cn, out[1:]))
    for n in cn:
        before, after = np.asarray(caches[n]), np.asarray(new[n])
        np.testing.assert_array_equal(before[0], after[0])
        np.testing.assert_array_equal(before[2], after[2])
        # selected row: slots outside 8..12 pass through untouched
        np.testing.assert_array_equal(before[1, :8], after[1, :8])
        np.testing.assert_array_equal(before[1, 12:], after[1, 12:])
        assert not np.array_equal(before[1, 8:12], after[1, 8:12])
    assert out[0].shape == (1, cfg.vocab_size)
    # an off-grid tail (start_pos + t >= S) writes nothing at all
    out = cfn(jnp.asarray([window], jnp.int32), jnp.int32(s), jnp.int32(0),
              oh, *flat, *[caches[n] for n in cn])
    for n, t in zip(cn, out[1:]):
        np.testing.assert_array_equal(np.asarray(caches[n]), np.asarray(t))


def test_chunked_prefill_adapters_matches_monolithic_stacked():
    """The adapter-stacked chunk window admits each row under its own
    adapter slot, identically to the stacked monolithic prefill."""
    cfg = CFG
    b, s, n = 3, 20, 3
    params = _params(cfg)
    _, stacked = _adapter_stack(cfg, n)
    prompts = [[1, 2, 3, 4, 5, 6, 7, 8, 9], [9, 8, 7], [5, 6, 4, 3]]
    row_ix = [0, 1, 2]
    pfn, pn, ln, cn = M.make_decode_prefill_adapters(cfg, n)
    cfn, *_ = M.make_decode_prefill_chunk_adapters(cfg, n)
    sfn, *_ = M.make_decode_step_adapters(cfg, n)
    shapes = M.kv_cache_shapes(cfg, b, s)
    flat = [params[k] for k in pn] + [stacked[k] for k in ln]
    mono = {nm: jnp.zeros(shapes[nm], jnp.float32) for nm in cn}
    for row, p in enumerate(prompts):
        toks = jnp.asarray([list(p) + [0] * (s - len(p))], jnp.int32)
        oh = jnp.zeros((b,), jnp.float32).at[row].set(1.0)
        out = pfn(toks, jnp.int32(len(p) - 1), oh, jnp.int32(row_ix[row]),
                  *flat, *[mono[nm] for nm in cn])
        mono = dict(zip(cn, out[1:]))
    chunked = {nm: jnp.zeros(shapes[nm], jnp.float32) for nm in cn}
    for row, p in enumerate(prompts):
        chunked, logits = _chunk_admit(cfg, cfn, flat, cn, chunked, row, p,
                                       b, ladder=[4], adapter_ix=row_ix[row])
    for nm in cn:
        for row, p in enumerate(prompts):
            np.testing.assert_allclose(
                np.asarray(chunked[nm])[row, :len(p)],
                np.asarray(mono[nm])[row, :len(p)], rtol=2e-3, atol=2e-3)
    # greedy continuation under per-row adapters matches across admissions
    ix = jnp.asarray(row_ix, jnp.int32)
    seqs = {"mono": [list(p) for p in prompts],
            "chunk": [list(p) for p in prompts]}
    caches = {"mono": mono, "chunk": chunked}
    for _ in range(4):
        outs = {}
        for kind in ("mono", "chunk"):
            sq = seqs[kind]
            toks = jnp.asarray([[q[-1]] for q in sq], jnp.int32)
            pos = jnp.asarray([len(q) - 1 for q in sq], jnp.int32)
            out = sfn(toks, pos, ix, *flat, *[caches[kind][nm] for nm in cn])
            caches[kind] = dict(zip(cn, out[1:]))
            outs[kind] = [int(jnp.argmax(out[0][r])) for r in range(b)]
            for r, q in enumerate(sq):
                q.append(outs[kind][r])
        assert outs["mono"] == outs["chunk"]


# ---------------------------------------------------------------------------
# Speculative decoding: the (B, K+1) verify window (DESIGN.md §2d)
# ---------------------------------------------------------------------------

def _prefill_caches(cfg, flat, cn, prompts, b, s):
    pfn, *_ = M.make_decode_prefill(cfg)
    shapes = M.kv_cache_shapes(cfg, b, s)
    caches = {n: jnp.zeros(shapes[n], jnp.float32) for n in cn}
    for row, p in enumerate(prompts):
        toks = jnp.asarray([list(p) + [0] * (s - len(p))], jnp.int32)
        oh = jnp.zeros((b,), jnp.float32).at[row].set(1.0)
        out = pfn(toks, jnp.int32(len(p) - 1), oh,
                  *flat, *[caches[n] for n in cn])
        caches = dict(zip(cn, out[1:]))
    return caches


def _step_greedy_streams(cfg, flat, cn, prompts, steps, s):
    """Reference: the pure `make_decode_step` greedy stream per row."""
    b = len(prompts)
    sfn, *_ = M.make_decode_step(cfg)
    caches = _prefill_caches(cfg, flat, cn, prompts, b, s)
    seqs = [list(p) for p in prompts]
    streams = [[] for _ in range(b)]
    for _ in range(steps):
        toks = jnp.asarray([[seq[-1]] for seq in seqs], jnp.int32)
        pos = jnp.asarray([len(seq) - 1 for seq in seqs], jnp.int32)
        out = sfn(toks, pos, *flat, *[caches[n] for n in cn])
        caches = dict(zip(cn, out[1:]))
        for r, seq in enumerate(seqs):
            t = int(jnp.argmax(out[0][r]))
            streams[r].append(t)
            seq.append(t)
    return streams


def _spec_greedy_streams(cfg, tflat, dflat, cn, prompts, steps, s, K,
                         tables=None, blk=None):
    """Draft/verify/rewind loop — the python mirror of the Rust
    `SpecDecoder` round. `dflat` is the drafter's weight stack (a different
    model, so drafts are imperfect and rejections actually happen).

    "Rewind" is logical, exactly as on the Rust side: rejected drafts'
    K/V stay in the cache tensors beyond each row's frontier, and
    correctness relies on later writes/attention masking them out.

    With `tables`/`blk` set, the same loop runs through the paged decode
    family instead (both models sharing the trivial block allocation) —
    logical rewind then means rejected drafts' K/V stay in the row's own
    pool blocks past the frontier, masked out exactly like dense."""
    b = len(prompts)
    if tables is None:
        sfn, *_ = M.make_decode_step(cfg)
        vfn, *_ = M.make_decode_verify(cfg)
        tcaches = _prefill_caches(cfg, tflat, cn, prompts, b, s)
        dcaches = _prefill_caches(cfg, dflat, cn, prompts, b, s)
    else:
        n_blocks = b * (s // blk)
        sfn_p, *_ = M.make_decode_step_paged(cfg)
        vfn_p, *_ = M.make_decode_verify_paged(cfg)
        sfn = lambda toks, pos, *rest: sfn_p(toks, pos, tables, *rest)
        vfn = lambda toks, pos, *rest: vfn_p(toks, pos, tables, *rest)
        tcaches = _paged_prefill_caches(cfg, tflat, cn, prompts, tables,
                                        n_blocks, blk, s)
        dcaches = _paged_prefill_caches(cfg, dflat, cn, prompts, tables,
                                        n_blocks, blk, s)
    seqs = [list(p) for p in prompts]
    streams = [[] for _ in range(b)]
    rounds = accepted_total = 0
    while any(len(st) < steps for st in streams):
        rounds += 1
        assert rounds <= b * steps + 8, "spec loop failed to make progress"
        active = [r for r in range(b) if len(streams[r]) < steps]
        k_eff = {r: min(K, steps - len(streams[r]) - 1, s - len(seqs[r]))
                 for r in active}
        # ---- draft k_eff tokens greedily + one write-only sync step ------
        drafts = {r: [] for r in active}
        for t in range(max(k_eff.values()) + 1):
            toks, pos = [], []
            for r in range(b):
                if r in active and t <= k_eff[r]:
                    toks.append([seqs[r][-1] if t == 0 else drafts[r][t - 1]])
                    pos.append(len(seqs[r]) - 1 + t)
                else:
                    toks.append([0])
                    pos.append(s)  # off-grid: writes nothing
            out = sfn(jnp.asarray(toks, jnp.int32),
                      jnp.asarray(pos, jnp.int32),
                      *dflat, *[dcaches[n] for n in cn])
            dcaches = dict(zip(cn, out[1:]))
            for r in active:
                if t < k_eff[r]:
                    drafts[r].append(int(jnp.argmax(out[0][r])))
        # ---- one batched verification of every row's window --------------
        toks, pos = [], []
        for r in range(b):
            if r in active:
                toks.append([seqs[r][-1]] + drafts[r]
                            + [0] * (K - k_eff[r]))
                pos.append(len(seqs[r]) - 1)
            else:
                toks.append([0] * (K + 1))
                pos.append(s)
        out = vfn(jnp.asarray(toks, jnp.int32), jnp.asarray(pos, jnp.int32),
                  *tflat, *[tcaches[n] for n in cn])
        tcaches = dict(zip(cn, out[1:]))
        # ---- accept the longest matching prefix + 1 correction token -----
        for r in active:
            tgt = [int(jnp.argmax(out[0][r, t])) for t in range(k_eff[r] + 1)]
            a = 0
            while a < k_eff[r] and drafts[r][a] == tgt[a]:
                a += 1
            accepted_total += a
            for t in tgt[:min(a + 1, steps - len(streams[r]))]:
                streams[r].append(t)
                seqs[r].append(t)
    return streams, rounds, accepted_total


def _assert_spec_matches_step_greedy(cfg, prompts, steps, s, K=3):
    params = _params(cfg)
    lora = _nonzero_lora(cfg)
    pn = M.param_names(cfg)
    ln = M.lora_names(cfg)
    cn = M.kv_cache_names(cfg)
    tflat = [params[k] for k in pn] + [lora[k] for k in ln]
    # drafter = the target slightly perturbed: a proxy close enough to get
    # drafts accepted, imperfect enough that rejections actually happen
    key = jax.random.PRNGKey(99)
    dl = {k: (v + 0.01 * jax.random.normal(jax.random.fold_in(key, i),
                                           v.shape)
              if k.endswith("lora_b") else v)
          for i, (k, v) in enumerate(lora.items())}
    dflat = [params[k] for k in pn] + [dl[k] for k in ln]
    ref = _step_greedy_streams(cfg, tflat, cn, prompts, steps, s)
    spec, rounds, accepted = _spec_greedy_streams(
        cfg, tflat, dflat, cn, prompts, steps, s, K)
    assert spec == ref, f"speculative stream diverged: {spec} vs {ref}"
    return rounds, accepted


def test_spec_verify_loop_reproduces_step_greedy_stream():
    """Greedy speculative decoding is lossless: the draft/verify/rewind
    loop over `make_decode_verify` emits byte-identical streams to the
    pure `make_decode_step` decode, rejections and all."""
    steps, K = 8, 3
    rounds, accepted = _assert_spec_matches_step_greedy(
        CFG, prompts=[[1, 2, 3, 4, 5], [9, 8, 7]], steps=steps, s=28, K=K)
    # the run must exercise BOTH outcome paths, or the matrix is vacuous:
    # some drafts accepted (multi-token rounds) ...
    assert accepted > 0, "no draft was ever accepted across the run"
    # ... and some rejected (more rounds than the all-accepted minimum)
    assert rounds > -(-steps // (K + 1)), "no draft was ever rejected"


def test_spec_verify_loop_gqa_and_pruned_plan():
    """GQA (kv < h) and a pruned layer plan with non-dividing head counts
    must round-trip through the verify window too."""
    gqa = ModelConfig(name="gqa4", d_model=64, n_layers=2, n_heads=4,
                      n_kv_heads=2, d_ff=96, max_seq=32)
    _assert_spec_matches_step_greedy(
        gqa, prompts=[[5, 6, 7], [11, 12, 13, 14]], steps=6, s=24)
    pruned = ModelConfig(name="pp", d_model=64, n_layers=2, n_heads=4,
                         n_kv_heads=2, d_ff=96, max_seq=32,
                         layer_plan=[[4, 2, 96], [3, 2, 64]])
    _assert_spec_matches_step_greedy(
        pruned, prompts=[[3, 1, 4, 1], [2, 7]], steps=6, s=24)


def test_decode_verify_window_matches_reforward_positions():
    """Every verify-window position's logits must match the full reforward
    at that position (the per-position analogue of the kv step test)."""
    cfg = CFG
    b, s, K = 2, 24, 4
    params = _params(cfg)
    lora = _nonzero_lora(cfg)
    pn, ln, cn = (M.param_names(cfg), M.lora_names(cfg), M.kv_cache_names(cfg))
    flat = [params[k] for k in pn] + [lora[k] for k in ln]
    vfn, *_ = M.make_decode_verify(cfg)
    prompts = [[1, 2, 3, 4, 5], [9, 8, 7]]
    caches = _prefill_caches(cfg, flat, cn, prompts, b, s)
    rng = np.random.default_rng(3)
    windows = [[p[-1]] + list(rng.integers(1, cfg.vocab_size, K))
               for p in prompts]
    out = vfn(jnp.asarray(windows, jnp.int32),
              jnp.asarray([len(p) - 1 for p in prompts], jnp.int32),
              *flat, *[caches[n] for n in cn])
    proj = M.ProjCtx(params, lora=lora, cfg=cfg)
    for r, p in enumerate(prompts):
        full = list(p) + windows[r][1:]
        grid = jnp.asarray([full + [0] * (s - len(full))], jnp.int32)
        ref = M.forward(cfg, proj, grid)[0]
        for t in range(K + 1):
            ref_row = ref[len(p) - 1 + t]
            np.testing.assert_allclose(out[0][r, t], ref_row,
                                       rtol=2e-3, atol=2e-3)
            assert int(jnp.argmax(out[0][r, t])) == int(jnp.argmax(ref_row))


def test_decode_verify_offgrid_window_writes_nothing():
    """A dummy row (pos >= S) must leave every cache bitwise intact — the
    contract that lets free/finished rows ride the batched verify call."""
    cfg = CFG
    b, s, K = 2, 16, 3
    params = _params(cfg)
    lora = _nonzero_lora(cfg)
    pn, ln, cn = (M.param_names(cfg), M.lora_names(cfg), M.kv_cache_names(cfg))
    flat = [params[k] for k in pn] + [lora[k] for k in ln]
    vfn, *_ = M.make_decode_verify(cfg)
    shapes = M.kv_cache_shapes(cfg, b, s)
    rng = np.random.default_rng(0)
    caches = {n: jnp.asarray(rng.normal(size=shapes[n]), jnp.float32)
              for n in cn}
    out = vfn(jnp.asarray([[0] * (K + 1)] * b, jnp.int32),
              jnp.asarray([s, s + 5], jnp.int32),
              *flat, *[caches[n] for n in cn])
    new = dict(zip(cn, out[1:]))
    for n in cn:
        np.testing.assert_array_equal(np.asarray(caches[n]),
                                      np.asarray(new[n]))


def test_decode_verify_adapters_matches_stacked_reforward():
    """The adapter-stacked verify window scores each row's drafts under
    that row's own adapter slot."""
    cfg = CFG
    b, s, K, n = 3, 20, 3, 3
    params = _params(cfg)
    _, stacked = _adapter_stack(cfg, n)
    prompts = [[1, 2, 3, 4], [9, 8, 7], [5, 6]]
    row_ix = [0, 1, 2]
    pfn, pn, ln, cn = M.make_decode_prefill_adapters(cfg, n)
    vfn, *_ = M.make_decode_verify_adapters(cfg, n)
    lfn, *_ = M.make_logits_adapters(cfg, n)
    shapes = M.kv_cache_shapes(cfg, b, s)
    caches = {nm: jnp.zeros(shapes[nm], jnp.float32) for nm in cn}
    flat = [params[k] for k in pn] + [stacked[k] for k in ln]
    for row, p in enumerate(prompts):
        toks = jnp.asarray([list(p) + [0] * (s - len(p))], jnp.int32)
        oh = jnp.zeros((b,), jnp.float32).at[row].set(1.0)
        out = pfn(toks, jnp.int32(len(p) - 1), oh, jnp.int32(row_ix[row]),
                  *flat, *[caches[nm] for nm in cn])
        caches = dict(zip(cn, out[1:]))
    rng = np.random.default_rng(5)
    windows = [[p[-1]] + list(rng.integers(1, cfg.vocab_size, K))
               for p in prompts]
    ix = jnp.asarray(row_ix, jnp.int32)
    out = vfn(jnp.asarray(windows, jnp.int32),
              jnp.asarray([len(p) - 1 for p in prompts], jnp.int32),
              ix, *flat, *[caches[nm] for nm in cn])
    for r, p in enumerate(prompts):
        full = list(p) + windows[r][1:]
        grid = jnp.asarray([f + [0] * (s - len(f))
                            for f in [full] * b], jnp.int32)
        ref = lfn(grid, ix, *flat)[0][r]
        for t in range(K + 1):
            ref_row = ref[len(p) - 1 + t]
            np.testing.assert_allclose(out[0][r, t], ref_row,
                                       rtol=2e-3, atol=2e-3)
            assert int(jnp.argmax(out[0][r, t])) == int(jnp.argmax(ref_row))


# ---------------------------------------------------------------------------
# Multi-adapter serving (stacked LoRA + per-row adapter_ix gather)
# ---------------------------------------------------------------------------

N_ADAPTERS = 3


def _adapter_stack(cfg, n=N_ADAPTERS):
    """n distinct adapters (nonzero b) + their stacked form."""
    loras = []
    for i in range(n):
        l = M.init_lora(cfg, jax.random.PRNGKey(40 + i))
        loras.append({k: (v if k.endswith("lora_a") else
                          jax.random.normal(jax.random.PRNGKey(70 + i),
                                            v.shape) * 0.05)
                      for k, v in l.items()})
    stacked = {k: jnp.stack([l[k] for l in loras]) for k in loras[0]}
    return loras, stacked


def _merge_adapter(cfg, params, lora):
    """Offline merge W' = W + s·a@b — the deployment-shape reference each
    stacked-adapter row must match."""
    scale = cfg.lora_alpha / cfg.lora_rank
    merged = dict(params)
    for i in range(cfg.n_layers):
        for k in M.LAYER_PROJ:
            nm = f"l{i}.{k}"
            merged[nm] = params[nm] + scale * (
                lora[f"{nm}.lora_a"] @ lora[f"{nm}.lora_b"])
    if cfg.lora_lm_head:
        merged["lm_head"] = params["lm_head"] + scale * (
            lora["lm_head.lora_a"] @ lora["lm_head.lora_b"])
    return merged


def test_stacked_adapter_rows_match_per_adapter_offline_merge():
    """A heterogeneous-adapter batch through the stacked artifact: row r
    with adapter_ix=i must equal the offline merge of adapter i."""
    cfg = CFG
    params = _params(cfg)
    loras, stacked = _adapter_stack(cfg)
    fn, pn, ln = M.make_logits_adapters(cfg, N_ADAPTERS)
    toks = _tokens(cfg, 4, 16)
    ix = jnp.asarray([2, 0, 1, 2], jnp.int32)
    out = fn(toks, ix, *[params[k] for k in pn], *[stacked[k] for k in ln])[0]
    assert out.shape == (4, 16, cfg.vocab_size)
    for row in range(4):
        merged = _merge_adapter(cfg, params, loras[int(ix[row])])
        ref = M.forward(cfg, M.ProjCtx(merged, cfg=cfg), toks[row:row + 1])
        np.testing.assert_allclose(out[row], ref[0], rtol=2e-3, atol=2e-3)


def test_zero_adapter_slot_is_identity():
    """An all-zero stacked slot (the Session's zero-init state) must serve
    the bare base model."""
    cfg = CFG
    params = _params(cfg)
    _, stacked = _adapter_stack(cfg)
    zeroed = {k: v.at[1].set(0.0) for k, v in stacked.items()}
    fn, pn, ln = M.make_logits_adapters(cfg, N_ADAPTERS)
    toks = _tokens(cfg, 2, 12)
    ix = jnp.asarray([1, 1], jnp.int32)
    out = fn(toks, ix, *[params[k] for k in pn], *[zeroed[k] for k in ln])[0]
    base = M.forward(cfg, M.ProjCtx(params, cfg=cfg), toks)
    np.testing.assert_allclose(out, base, rtol=1e-5, atol=1e-5)


def test_adapter_decode_paths_match_stacked_reforward_greedy():
    """Mixed-adapter greedy decode through the stacked prefill/step pair
    must reproduce the stacked reforward logits (and token stream) row by
    row — the contract the Rust kv path relies on for adapter batches."""
    cfg = CFG
    b, s, steps = 3, 20, 5
    params = _params(cfg)
    _, stacked = _adapter_stack(cfg)
    prompts = [[1, 2, 3, 4], [9, 8, 7], [5, 6]]
    row_ix = [0, 1, 2]
    pfn, pn, ln, cn = M.make_decode_prefill_adapters(cfg, N_ADAPTERS)
    sfn, *_ = M.make_decode_step_adapters(cfg, N_ADAPTERS)
    lfn, *_ = M.make_logits_adapters(cfg, N_ADAPTERS)
    shapes = M.kv_cache_shapes(cfg, b, s)
    caches = {n: jnp.zeros(shapes[n], jnp.float32) for n in cn}
    flat = [params[k] for k in pn] + [stacked[k] for k in ln]
    for row, p in enumerate(prompts):
        toks = jnp.asarray([list(p) + [0] * (s - len(p))], jnp.int32)
        oh = jnp.zeros((b,), jnp.float32).at[row].set(1.0)
        out = pfn(toks, jnp.int32(len(p) - 1), oh, jnp.int32(row_ix[row]),
                  *flat, *[caches[n] for n in cn])
        caches = dict(zip(cn, out[1:]))
    seqs = [list(p) for p in prompts]
    ix = jnp.asarray(row_ix, jnp.int32)
    for _ in range(steps):
        toks = jnp.asarray([[seq[-1]] for seq in seqs], jnp.int32)
        pos = jnp.asarray([len(seq) - 1 for seq in seqs], jnp.int32)
        out = sfn(toks, pos, ix, *flat, *[caches[n] for n in cn])
        caches = dict(zip(cn, out[1:]))
        grid = jnp.asarray([seq + [0] * (s - len(seq)) for seq in seqs],
                           jnp.int32)
        ref = lfn(grid, ix, *flat)[0]
        for r, seq in enumerate(seqs):
            ref_row = ref[r, len(seq) - 1]
            np.testing.assert_allclose(out[0][r], ref_row,
                                       rtol=2e-3, atol=2e-3)
            assert int(jnp.argmax(out[0][r])) == int(jnp.argmax(ref_row))
            seq.append(int(jnp.argmax(ref_row)))
    # distinct adapters must actually steer the streams apart somewhere:
    # all three rows sharing one stream would void the routing claim
    tails = [tuple(seq[len(p):]) for seq, p in zip(seqs, prompts)]
    assert len(set(tails)) > 1, "every adapter produced the same stream"


def test_eval_loss_matches_mean_loss():
    cfg = CFG
    fn, pnames, lnames = M.make_eval_loss(cfg)
    params = _params(cfg)
    lora = _lora(cfg)
    toks = _tokens(cfg, 2, 17)
    mask = jnp.ones((2, 16), jnp.float32)
    s, c = fn(toks, mask, *[params[k] for k in pnames],
              *[lora[k] for k in lnames])
    proj = M.ProjCtx(params, lora=lora, cfg=cfg)
    logits = M.forward(cfg, proj, toks[:, :-1])
    want = M.mean_loss(logits, toks[:, 1:], mask)
    np.testing.assert_allclose(float(s.sum() / c.sum()), float(want),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# Paged KV cache (DESIGN.md §2f: block pool + per-row block tables)
# ---------------------------------------------------------------------------

def _seq_tables(b, s, blk):
    """Trivial allocation: row r owns pool blocks [r*S/blk, (r+1)*S/blk)."""
    npr = s // blk
    return jnp.arange(b * npr, dtype=jnp.int32).reshape(b, npr)


def _paged_prefill_caches(cfg, flat, cn, prompts, tables, n_blocks, blk, s):
    """Monolithic paged admission of every prompt into a zeroed pool."""
    pfn, *_ = M.make_decode_prefill_paged(cfg)
    shapes = M.paged_cache_shapes(cfg, n_blocks, blk)
    caches = {n: jnp.zeros(shapes[n], jnp.float32) for n in cn}
    for row, p in enumerate(prompts):
        toks = jnp.asarray([list(p) + [0] * (s - len(p))], jnp.int32)
        out = pfn(toks, jnp.int32(len(p) - 1), tables[row],
                  *flat, *[caches[n] for n in cn])
        caches = dict(zip(cn, out[1:]))
    return caches


def _assert_paged_matches_dense(cfg, prompts, steps, s, blk, k=3):
    """The §2f acceptance contract: prefill logits, every greedy step's
    logits, and a trailing (B, K+1) verify window must all be BITWISE
    identical between the dense grid and the block pool — paging permutes
    storage, never values."""
    b = len(prompts)
    n_blocks = b * (s // blk)
    params = _params(cfg)
    lora = _nonzero_lora(cfg)
    pn, ln, cn = (M.param_names(cfg), M.lora_names(cfg), M.kv_cache_names(cfg))
    flat = [params[k2] for k2 in pn] + [lora[k2] for k2 in ln]
    tables = _seq_tables(b, s, blk)

    pfn_d, *_ = M.make_decode_prefill(cfg)
    pfn_p, *_ = M.make_decode_prefill_paged(cfg)
    dense = {n: jnp.zeros(shp, jnp.float32)
             for n, shp in M.kv_cache_shapes(cfg, b, s).items()}
    pool = {n: jnp.zeros(shp, jnp.float32)
            for n, shp in M.paged_cache_shapes(cfg, n_blocks, blk).items()}
    for row, p in enumerate(prompts):
        toks = jnp.asarray([list(p) + [0] * (s - len(p))], jnp.int32)
        oh = jnp.zeros((b,), jnp.float32).at[row].set(1.0)
        out_d = pfn_d(toks, jnp.int32(len(p) - 1), oh,
                      *flat, *[dense[n] for n in cn])
        out_p = pfn_p(toks, jnp.int32(len(p) - 1), tables[row],
                      *flat, *[pool[n] for n in cn])
        dense = dict(zip(cn, out_d[1:]))
        pool = dict(zip(cn, out_p[1:]))
        np.testing.assert_array_equal(np.asarray(out_d[0]),
                                      np.asarray(out_p[0]))

    sfn_d, *_ = M.make_decode_step(cfg)
    sfn_p, *_ = M.make_decode_step_paged(cfg)
    seqs = [list(p) for p in prompts]
    for _ in range(steps):
        toks = jnp.asarray([[seq[-1]] for seq in seqs], jnp.int32)
        pos = jnp.asarray([len(seq) - 1 for seq in seqs], jnp.int32)
        out_d = sfn_d(toks, pos, *flat, *[dense[n] for n in cn])
        out_p = sfn_p(toks, pos, tables, *flat, *[pool[n] for n in cn])
        dense = dict(zip(cn, out_d[1:]))
        pool = dict(zip(cn, out_p[1:]))
        np.testing.assert_array_equal(np.asarray(out_d[0]),
                                      np.asarray(out_p[0]))
        for seq, row in zip(seqs, np.asarray(out_d[0])):
            seq.append(int(row.argmax()))

    vfn_d, *_ = M.make_decode_verify(cfg)
    vfn_p, *_ = M.make_decode_verify_paged(cfg)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, k + 1)), jnp.int32)
    pos = jnp.asarray([len(seq) - 1 for seq in seqs], jnp.int32)
    out_d = vfn_d(toks, pos, *flat, *[dense[n] for n in cn])
    out_p = vfn_p(toks, pos, tables, *flat, *[pool[n] for n in cn])
    np.testing.assert_array_equal(np.asarray(out_d[0]), np.asarray(out_p[0]))


def test_paged_decode_matrix_bitwise_matches_dense():
    _assert_paged_matches_dense(
        CFG, prompts=[[1, 2, 3, 4, 5], [9, 8, 7]], steps=6, s=24, blk=8)


def test_paged_decode_gqa_and_pruned_plan_bitwise():
    gqa = ModelConfig(name="gqa4", d_model=64, n_layers=2, n_heads=4,
                      n_kv_heads=2, d_ff=96, max_seq=32)
    _assert_paged_matches_dense(
        gqa, prompts=[[5, 6, 7], [11, 12, 13, 14]], steps=4, s=16, blk=4)
    pruned = ModelConfig(name="pp", d_model=64, n_layers=2, n_heads=4,
                         n_kv_heads=2, d_ff=96, max_seq=32,
                         layer_plan=[[4, 2, 96], [3, 2, 64]])
    _assert_paged_matches_dense(
        pruned, prompts=[[3, 1, 4, 1], [2, 7]], steps=4, s=16, blk=4)


def test_paged_prefill_writes_only_owned_blocks():
    """A paged admission must leave every pool block outside the admitted
    row's table bitwise intact — the paged statement of mid-decode
    admission safety (the table IS the isolation boundary)."""
    cfg = CFG
    b, s, blk = 3, 16, 4
    n_blocks = b * (s // blk)
    params = _params(cfg)
    lora = _nonzero_lora(cfg)
    pn, ln, cn = (M.param_names(cfg), M.lora_names(cfg), M.kv_cache_names(cfg))
    flat = [params[k] for k in pn] + [lora[k] for k in ln]
    pfn, *_ = M.make_decode_prefill_paged(cfg)
    shapes = M.paged_cache_shapes(cfg, n_blocks, blk)
    rng = np.random.default_rng(0)
    caches = {n: jnp.asarray(rng.normal(size=shapes[n]), jnp.float32)
              for n in cn}
    tables = _seq_tables(b, s, blk)
    toks = jnp.asarray([[1, 2, 3] + [0] * (s - 3)], jnp.int32)
    out = pfn(toks, jnp.int32(2), tables[1], *flat, *[caches[n] for n in cn])
    new_caches = dict(zip(cn, out[1:]))
    owned = set(np.asarray(tables[1]).tolist())
    for n in cn:
        before, after = np.asarray(caches[n]), np.asarray(new_caches[n])
        for blk_id in range(n_blocks):
            if blk_id in owned:
                continue
            np.testing.assert_array_equal(before[blk_id], after[blk_id])
        assert not np.array_equal(before, after)
    assert out[0].shape == (1, cfg.vocab_size)


def test_paged_chunked_prefill_matches_monolithic_paged():
    """Chunked paged admission (windows through the row's table) lands the
    same pool bits and logits as the monolithic paged prefill."""
    cfg = CFG
    prompts = [[1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11], [4, 4, 2, 1]]
    b, s, blk, c = len(prompts), 16, 4, 8
    n_blocks = b * (s // blk)
    params = _params(cfg)
    lora = _nonzero_lora(cfg)
    pn, ln, cn = (M.param_names(cfg), M.lora_names(cfg), M.kv_cache_names(cfg))
    flat = [params[k] for k in pn] + [lora[k] for k in ln]
    tables = _seq_tables(b, s, blk)
    mono = _paged_prefill_caches(cfg, flat, cn, prompts, tables,
                                 n_blocks, blk, s)
    cfn, *_ = M.make_decode_prefill_chunk_paged(cfg)
    shapes = M.paged_cache_shapes(cfg, n_blocks, blk)
    caches = {n: jnp.zeros(shapes[n], jnp.float32) for n in cn}
    for row, p in enumerate(prompts):
        start, logits = 0, None
        while start < len(p):
            take = min(c, len(p) - start)
            window = list(p[start:start + take]) + [0] * (c - take)
            out = cfn(jnp.asarray([window], jnp.int32), jnp.int32(start),
                      jnp.int32(take - 1), tables[row],
                      *flat, *[caches[n] for n in cn])
            caches = dict(zip(cn, out[1:]))
            logits = out[0]
            start += take
        assert logits is not None
    # chunked == monolithic on the prompt positions of every owned block
    # (pad positions past a short final window differ by construction —
    # the monolithic prefill writes the full grid; both are dead slots)
    for row, p in enumerate(prompts):
        for n in cn:
            got, want = np.asarray(caches[n]), np.asarray(mono[n])
            for j in range(-(-len(p) // blk)):
                blk_id = int(tables[row, j])
                lo = j * blk
                hi = min(len(p) - lo, blk)
                np.testing.assert_array_equal(got[blk_id][:hi],
                                              want[blk_id][:hi])
    # and the continuation stream matches the monolithic pool's
    sfn, *_ = M.make_decode_step_paged(cfg)
    seqs_a = [list(p) for p in prompts]
    seqs_b = [list(p) for p in prompts]
    pool_a, pool_b = caches, mono
    for _ in range(4):
        toks_a = jnp.asarray([[sq[-1]] for sq in seqs_a], jnp.int32)
        toks_b = jnp.asarray([[sq[-1]] for sq in seqs_b], jnp.int32)
        pos = jnp.asarray([len(sq) - 1 for sq in seqs_a], jnp.int32)
        out_a = sfn(toks_a, pos, tables, *flat, *[pool_a[n] for n in cn])
        out_b = sfn(toks_b, pos, tables, *flat, *[pool_b[n] for n in cn])
        pool_a = dict(zip(cn, out_a[1:]))
        pool_b = dict(zip(cn, out_b[1:]))
        for r in range(b):
            ta = int(jnp.argmax(out_a[0][r]))
            tb = int(jnp.argmax(out_b[0][r]))
            assert ta == tb
            seqs_a[r].append(ta)
            seqs_b[r].append(tb)


def test_paged_shared_prefix_reuse_skips_resident_chunks():
    """The prefix-cache read path: a second row whose table aliases the
    first row's full prefix blocks is admitted by prefilling ONLY its
    non-resident suffix, and must decode exactly like a dense row that
    prefilled the whole prompt. Shared blocks stay bitwise intact through
    the alias row's admission and decode (reads never write; suffix and
    generated tokens land in private blocks only)."""
    cfg = CFG
    blk, s = 4, 16
    prefix = [7, 3, 9, 1, 5, 2, 8, 6]            # 2 full blocks
    tail_a, tail_b = [11, 12, 13], [4, 10]
    pa, pb = prefix + tail_a, prefix + tail_b
    b = 2
    params = _params(cfg)
    lora = _nonzero_lora(cfg)
    pn, ln, cn = (M.param_names(cfg), M.lora_names(cfg), M.kv_cache_names(cfg))
    flat = [params[k] for k in pn] + [lora[k] for k in ln]

    # dense reference: both rows fully admitted
    ref = _step_greedy_streams(cfg, flat, cn, [pa, pb], steps=5, s=s)

    # paged: row 0 owns blocks 0..3; row 1 aliases the prefix blocks 0..1
    # and owns private blocks 4..5 for its suffix + generated tokens
    n_blocks = 6
    tables = jnp.asarray([[0, 1, 2, 3], [0, 1, 4, 5]], jnp.int32)
    pool = _paged_prefill_caches(cfg, flat, cn, [pa], tables[:1],
                                 n_blocks, blk, s)
    shared_before = {n: np.asarray(pool[n])[:2].copy() for n in cn}
    # admit row 1: feed only the suffix window at start_pos = len(prefix)
    cfn, *_ = M.make_decode_prefill_chunk_paged(cfg)
    c = 8
    window = tail_b + [0] * (c - len(tail_b))
    out = cfn(jnp.asarray([window], jnp.int32), jnp.int32(len(prefix)),
              jnp.int32(len(tail_b) - 1), tables[1],
              *flat, *[pool[n] for n in cn])
    pool = dict(zip(cn, out[1:]))
    first_b = np.asarray(out[0][0])

    sfn, *_ = M.make_decode_step_paged(cfg)
    seqs = [list(pa), list(pb)]
    streams = [[], []]
    # row 1's first generated token comes from the suffix chunk's logits
    streams[1].append(int(first_b.argmax()))
    seqs[1].append(streams[1][0])
    for _ in range(5):
        toks = jnp.asarray([[sq[-1]] for sq in seqs], jnp.int32)
        pos = jnp.asarray([len(sq) - 1 for sq in seqs], jnp.int32)
        out = sfn(toks, pos, tables, *flat, *[pool[n] for n in cn])
        pool = dict(zip(cn, out[1:]))
        for r in range(b):
            t = int(jnp.argmax(out[0][r]))
            streams[r].append(t)
            seqs[r].append(t)
    assert streams[0][:5] == ref[0], "prefix-owner stream diverged"
    assert streams[1][:5] == ref[1], "prefix-alias stream diverged"
    for n in cn:
        np.testing.assert_array_equal(np.asarray(pool[n])[:2],
                                      shared_before[n])


def test_paged_spec_verify_loop_matches_dense_stream():
    """Greedy speculative decoding through the block pool — drafts,
    rejections, logical rewind and all — reproduces the dense spec loop's
    stream exactly (both equal the pure step-greedy reference)."""
    cfg = CFG
    prompts = [[1, 2, 3, 4, 5], [9, 8, 7]]
    steps, K, s, blk = 8, 3, 28, 4
    params = _params(cfg)
    lora = _nonzero_lora(cfg)
    pn = M.param_names(cfg)
    ln = M.lora_names(cfg)
    cn = M.kv_cache_names(cfg)
    tflat = [params[k] for k in pn] + [lora[k] for k in ln]
    key = jax.random.PRNGKey(99)
    dl = {k: (v + 0.01 * jax.random.normal(jax.random.fold_in(key, i),
                                           v.shape)
              if k.endswith("lora_b") else v)
          for i, (k, v) in enumerate(lora.items())}
    dflat = [params[k] for k in pn] + [dl[k] for k in ln]
    dense, _, _ = _spec_greedy_streams(cfg, tflat, dflat, cn, prompts,
                                       steps, s, K)
    tables = _seq_tables(len(prompts), s, blk)
    paged, _, accepted = _spec_greedy_streams(cfg, tflat, dflat, cn, prompts,
                                              steps, s, K,
                                              tables=tables, blk=blk)
    assert paged == dense, f"paged spec stream diverged: {paged} vs {dense}"
    assert accepted > 0, "no draft was ever accepted across the paged run"

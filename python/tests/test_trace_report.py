"""Offline trace-audit tooling tests (stdlib only — no jax, no cargo).

Exercises `tools/trace_report.py` against synthetic event streams: the
clean-lifecycle replay must reconstruct the exact TTFT/ITL tick vectors
(mirroring the `rust/src/obs/audit.rs` unit tests), each conservation law
must fire on a violating stream, and the percentile interpolation must
match `util::stats::percentile`'s spot values so the bit-for-bit `--check`
against an exported `serverStats` block is meaningful.
"""

import importlib.util
import json
import pathlib

import pytest

REPO = pathlib.Path(__file__).resolve().parents[2]


def _load(name, rel):
    spec = importlib.util.spec_from_file_location(name, REPO / rel)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


tr = _load("trace_report", "tools/trace_report.py")
sync = _load("event_sync_check", "tools/event_sync_check.py")


def ev(kind, tick, **fields):
    return {"kind": kind, "tick": tick, "wall_ms": 0.0, **fields}


def clean_lifecycle():
    """One request: enqueue@0, admit@1, tokens @2/@3/@5, finish@5.
    Same shape as audit.rs's `clean_lifecycle_passes` test."""
    return [
        ev("Enqueue", 0, req=0),
        ev("Admit", 1, req=0, row=0),
        ev("PrefillWindow", 1, row=0, start=0, bucket=16),
        ev("DecodeStep", 2, row=0),
        ev("DecodeStep", 3, row=0),
        ev("DecodeStep", 5, row=0),
        ev("Finish", 5, req=0, row=0, tokens=3),
        ev("Evict", 5, row=0),
    ]


# ---------------------------------------------------------------- replay


def test_clean_lifecycle_passes_and_reconstructs_latency_vectors():
    r = tr.audit(clean_lifecycle())
    assert r["violations"] == []
    assert (r["enqueued"], r["admitted"], r["finished"]) == (1, 1, 1)
    assert r["tokens"] == 3
    # TTFT = first token tick - enqueue tick; ITL = successive gaps
    assert r["ttft_ticks"] == [2]
    assert r["itl_ticks"] == [1, 2]


def test_token_conservation_violation_is_caught():
    events = clean_lifecycle()
    events[6] = ev("Finish", 5, req=0, row=0, tokens=7)  # lies about count
    r = tr.audit(events)
    assert any("Finish says 7" in v for v in r["violations"])


def test_token_on_unoccupied_row_is_caught():
    r = tr.audit([ev("DecodeStep", 3, row=4)])
    assert any("unoccupied row 4" in v for v in r["violations"])


def test_admit_over_live_row_is_caught():
    events = [
        ev("Enqueue", 0, req=0),
        ev("Enqueue", 0, req=1),
        ev("Admit", 1, req=0, row=0),
        ev("Admit", 1, req=1, row=0),  # row 0 still occupied by req 0
    ]
    r = tr.audit(events)
    assert any("admit req 1 over live req 0" in v for v in r["violations"])


def test_admitted_but_never_finished_is_caught():
    r = tr.audit([ev("Enqueue", 0, req=0), ev("Admit", 1, req=0, row=0)])
    assert any("never finished" in v for v in r["violations"])
    assert any("still occupied" in v for v in r["violations"])


def test_block_ledger_discipline():
    ok = tr.audit([
        ev("BlockAlloc", 0, block=3),
        ev("BlockFree", 1, block=3),
        ev("BlockAlloc", 2, block=3),
    ])
    assert ok["violations"] == []
    assert ok["live_blocks"] == 1

    double = tr.audit([ev("BlockAlloc", 0, block=3), ev("BlockAlloc", 1, block=3)])
    assert any("allocated while live" in v for v in double["violations"])

    stray = tr.audit([ev("BlockFree", 0, block=9)])
    assert any("freed while free" in v for v in stray["violations"])


def test_preempt_conserves_tokens_and_frees_row_for_reuse():
    # same shape as audit.rs's preempt_conserves_tokens test: preempt
    # discards 2 tokens, the row is immediately reusable, and the victim's
    # second life re-finishes with a clean token slate
    events = [
        ev("Enqueue", 0, req=0),
        ev("Admit", 0, req=0, row=0),
        ev("DecodeStep", 1, row=0),   # ttft = 1 (first-ever token)
        ev("DecodeStep", 2, row=0),   # itl = 1
        ev("Preempt", 3, req=0, row=0, tokens=2),
        ev("Evict", 3, row=0),
        ev("Enqueue", 3, req=1),
        ev("Admit", 3, req=1, row=0),  # freed row is reusable
        ev("DecodeStep", 4, row=0),
        ev("Finish", 4, req=1, row=0, tokens=1),
        ev("Admit", 5, req=0, row=1),  # re-admit after preempt
        ev("DecodeStep", 6, row=1),    # no TTFT (already recorded)
        ev("DecodeStep", 7, row=1),    # itl = 1, no cross-life gap
        ev("DecodeStep", 8, row=1),
        ev("Finish", 8, req=0, row=1, tokens=3),
    ]
    r = tr.audit(events)
    assert r["violations"] == []
    assert (r["preempted"], r["preempted_tokens"]) == (1, 2)
    # global conservation: DecodeSteps == finish tokens + discarded
    assert r["tokens"] == 3 + 1 + 2
    assert r["ttft_ticks"] == [1, 1]
    assert r["itl_ticks"] == [1, 1, 1]


def test_preempt_token_lie_and_unadmitted_preempt_are_caught():
    events = [
        ev("Enqueue", 0, req=0),
        ev("Admit", 0, req=0, row=0),
        ev("DecodeStep", 1, row=0),
        ev("Preempt", 2, req=0, row=0, tokens=5),  # lies: life sampled 1
        ev("Preempt", 3, req=0, row=2, tokens=0),  # not admitted any more
    ]
    text = "\n".join(tr.audit(events)["violations"])
    assert "Preempt says 5 tokens but life sampled 1" in text
    assert "preempt on unoccupied row 2" in text
    assert "preempted while not admitted" in text


def test_cancel_is_terminal_and_pre_admission():
    clean = tr.audit([ev("Enqueue", 0, req=0), ev("Cancel", 4, req=0)])
    assert clean["violations"] == []
    assert clean["cancelled"] == 1

    bad = [
        ev("Enqueue", 0, req=0),
        ev("Admit", 0, req=0, row=0),
        ev("Cancel", 1, req=0),        # in flight: not cancellable
        ev("Admit", 2, req=0, row=1),  # nothing after cancel
    ]
    text = "\n".join(tr.audit(bad)["violations"])
    assert "cancelled while in flight" in text
    assert "admitted after cancel" in text


def test_deadline_miss_requires_a_finish_and_ledger_balances():
    late = [
        ev("Enqueue", 0, req=0),
        ev("Admit", 0, req=0, row=0),
        ev("DecodeStep", 9, row=0),
        ev("DeadlineMiss", 9, req=0),
        ev("Finish", 9, req=0, row=0, tokens=1),
    ]
    r = tr.audit(late)
    assert r["violations"] == []
    assert r["deadline_misses"] == 1

    orphan = tr.audit([ev("DeadlineMiss", 0, req=3)])
    assert any("deadline miss without a finish" in v
               for v in orphan["violations"])

    # an admission with no terminal event breaks the admission ledger
    open_adm = tr.audit([ev("Enqueue", 0, req=0), ev("Admit", 0, req=0, row=0)])
    assert any("admission ledger broken" in v for v in open_adm["violations"])


def test_mid_flight_reject_balances_the_ledger():
    events = [
        ev("Enqueue", 0, req=0),
        ev("Admit", 0, req=0, row=0),
        ev("Reject", 1, req=0),  # forced admission aborted mid-flight
    ]
    r = tr.audit(events)
    assert r["violations"] == []
    assert (r["admitted"], r["finished"], r["rejected"]) == (1, 0, 1)


def test_verify_round_cannot_accept_more_than_drafted():
    r = tr.audit([ev("VerifyRound", 2, row=0, k=4, accepted=5)])
    assert any("accepted 5 > drafted 4" in v for v in r["violations"])


def test_unknown_kind_and_missing_fields_are_violations():
    r = tr.audit([ev("Teleport", 0), {"kind": "Admit", "tick": 1, "req": 0}])
    assert any("unknown kind 'Teleport'" in v for v in r["violations"])
    assert any("missing fields ['row']" in v for v in r["violations"])


# ------------------------------------------------- chaos laws (Sec 2j)


def retried_lifecycle():
    """One request that faults once mid-decode, is preempted/retried, and
    finishes on its second life — the clean shape for laws 9-11. Same
    shape as audit.rs's retried lifecycle test."""
    return [
        ev("Enqueue", 0, req=0),
        ev("Admit", 1, req=0, row=0),
        ev("DecodeStep", 2, row=0),
        ev("Fault", 3, req=0, row=0, fault="decode-transient"),
        ev("Preempt", 3, req=0, row=0, tokens=1),
        ev("Retry", 3, req=0, attempt=1),
        ev("Admit", 5, req=0, row=0),
        ev("DecodeStep", 6, row=0),
        ev("DecodeStep", 7, row=0),
        ev("Finish", 7, req=0, row=0, tokens=2),
    ]


def test_retried_lifecycle_passes_and_counts_the_retry_ledger():
    r = tr.audit(retried_lifecycle())
    assert r["violations"] == []
    assert (r["faults"], r["retries"], r["failed"]) == (1, 1, 0)
    # the faulted life's token is conserved like any preemption
    assert r["preempted_tokens"] == 1


def test_retry_without_a_pending_fault_is_caught():
    events = [
        ev("Enqueue", 0, req=0),
        ev("Admit", 1, req=0, row=0),
        ev("Retry", 2, req=0, attempt=1),  # no Fault preceded it
    ]
    r = tr.audit(events)
    assert any("retry without a pending fault" in v for v in r["violations"])


def test_retry_attempt_number_lie_is_caught():
    events = retried_lifecycle()
    events[5] = ev("Retry", 3, req=0, attempt=7)
    r = tr.audit(events)
    assert any("Retry says attempt 7 but this is retry 1" in v
               for v in r["violations"])


def test_fault_placement_violations_are_caught():
    text = "\n".join(tr.audit([
        ev("Fault", 0, req=5, row=0, fault="decode-transient"),
        ev("Enqueue", 1, req=0),
        ev("Admit", 1, req=0, row=0),
        ev("Fault", 2, req=0, row=3, fault="decode-transient"),
    ])["violations"])
    assert "req 5: fault while not admitted" in text
    assert "req 0: fault on row 3 it does not occupy" in text


def test_failed_token_and_attempt_lies_are_caught():
    events = [
        ev("Enqueue", 0, req=0),
        ev("Admit", 1, req=0, row=0),
        ev("DecodeStep", 2, row=0),
        ev("Fault", 3, req=0, row=0, fault="decode-transient"),
        ev("Failed", 3, req=0, tokens=9, attempts=2),  # sampled 1, 1 fault
    ]
    text = "\n".join(tr.audit(events)["violations"])
    assert "Failed says 9 tokens but life sampled 1" in text
    assert "Failed says 2 attempts but life took 1 faults" in text


def test_terminal_failure_conserves_tokens_and_balances_the_ledger():
    events = [
        ev("Enqueue", 0, req=0),
        ev("Admit", 1, req=0, row=0),
        ev("DecodeStep", 2, row=0),
        ev("Fault", 3, req=0, row=0, fault="decode-transient"),
        ev("Failed", 3, req=0, tokens=1, attempts=1),
        ev("Evict", 3, row=0),
    ]
    r = tr.audit(events)
    assert r["violations"] == []
    assert (r["faults"], r["retries"], r["failed"]) == (1, 0, 1)
    assert r["failed_tokens"] == 1


def test_dangling_fault_at_end_of_trace_is_caught():
    events = [
        ev("Enqueue", 0, req=0),
        ev("Admit", 1, req=0, row=0),
        ev("Fault", 2, req=0, row=0, fault="decode-transient"),
        ev("Preempt", 2, req=0, row=0, tokens=0),
    ]
    r = tr.audit(events)
    assert any("retry ledger broken at end of trace" in v
               for v in r["violations"])


def test_failure_is_terminal():
    events = [
        ev("Enqueue", 0, req=0),
        ev("Admit", 1, req=0, row=0),
        ev("Fault", 2, req=0, row=0, fault="decode-transient"),
        ev("Failed", 2, req=0, tokens=0, attempts=1),
        ev("Enqueue", 3, req=0),  # nothing may name req 0 again
    ]
    r = tr.audit(events)
    assert any("Enqueue after Failed (failure is terminal)" in v
               for v in r["violations"])


def test_degradation_brackets_cleanly_and_violations_fire():
    clean = tr.audit([ev("Degrade", 1, level="degraded"), ev("Recover", 4)])
    assert clean["violations"] == []
    assert clean["degrades"] == 1

    # escalation to failing is a legal close for a degraded bracket
    escalate = tr.audit([
        ev("Degrade", 1, level="degraded"),
        ev("Degrade", 3, level="failing"),
    ])
    assert escalate["violations"] == []

    text = "\n".join(tr.audit([
        ev("Recover", 0),
        ev("Degrade", 1, level="degraded"),
        ev("Degrade", 2, level="degraded"),
    ])["violations"])
    assert "recover while healthy" in text
    assert "degrade to degraded while degraded" in text
    assert "degradation never closed: trace ends degraded, not failing" in text

    text = "\n".join(tr.audit([
        ev("Degrade", 0, level="failing"),
        ev("Recover", 1),
        ev("Degrade", 2, level="failing"),
    ])["violations"])
    assert "recover from failing (failing is terminal)" in text
    assert "degrade to failing while already failing" in text

    weird = tr.audit([ev("Degrade", 0, level="borked")])
    assert any("unknown degrade level 'borked'" in v
               for v in weird["violations"])


# ------------------------------------------------------------ percentile


@pytest.mark.parametrize(
    "xs, p, want",
    [
        ([], 50.0, 0.0),
        ([7.0], 99.0, 7.0),
        ([1.0, 2.0, 3.0, 4.0, 5.0], 0.0, 1.0),
        ([1.0, 2.0, 3.0, 4.0, 5.0], 25.0, 2.0),
        ([1.0, 2.0, 3.0, 4.0, 5.0], 50.0, 3.0),
        ([1.0, 2.0, 3.0, 4.0, 5.0], 100.0, 5.0),
        ([1.0, 2.0], 50.0, 1.5),  # lerp between straddling samples
        ([1.0, 2.0, 3.0, 4.0], 50.0, 2.5),
    ],
)
def test_percentile_matches_rust_stats_spot_values(xs, p, want):
    # same spot values as util::stats' unit tests — the formula must be
    # the rank = (p/100)*(n-1) lerp, not nearest-rank
    assert tr.percentile(xs, p) == want


# ----------------------------------------------------------- check gate


def _stats_for(report):
    return {
        "served": report["finished"],
        "rejected": report["rejected"],
        "total_tokens": report["tokens"],
        "ttft_tick_p50": tr.percentile(report["ttft_ticks"], 50.0),
        "ttft_tick_p95": tr.percentile(report["ttft_ticks"], 95.0),
        "itl_tick_p50": tr.percentile(report["itl_ticks"], 50.0),
        "itl_tick_p95": tr.percentile(report["itl_ticks"], 95.0),
    }


def test_check_passes_on_consistent_trace():
    r = tr.audit(clean_lifecycle())
    assert tr.check(r, _stats_for(r), {"dropped": 0}) == []


def test_check_fails_on_percentile_mismatch_dropped_events_and_cow():
    r = tr.audit(clean_lifecycle())
    stats = _stats_for(r)
    stats["ttft_tick_p50"] = stats["ttft_tick_p50"] + 0.25
    errs = tr.check(r, stats, {})
    assert any("ttft p50" in e for e in errs)

    errs = tr.check(r, _stats_for(r), {"dropped": 3})
    assert any("dropped 3 events" in e for e in errs)

    cow = tr.audit(clean_lifecycle() + [ev("CowCopy", 4, block=2)])
    errs = tr.check(cow, _stats_for(cow), {})
    assert any("copy-on-write" in e for e in errs)


def test_check_covers_slo_counters_and_goodput_bitwise():
    events = [
        ev("Enqueue", 0, req=0),
        ev("Admit", 0, req=0, row=0),
        ev("DecodeStep", 9, row=0),
        ev("DeadlineMiss", 9, req=0),
        ev("Finish", 9, req=0, row=0, tokens=1),
        ev("Enqueue", 0, req=1),
        ev("Cancel", 3, req=1),
    ]
    r = tr.audit(events)
    stats = _stats_for(r)
    stats.update({
        "preempted": 0,
        "cancelled": 1,
        "deadline_misses": 1,
        # (served - misses) / max(served + cancelled, 1) = 0/2
        "goodput": 0.0,
    })
    assert tr.check(r, stats, {"dropped": 0}) == []

    stats["cancelled"] = 2
    errs = tr.check(r, stats, {"dropped": 0})
    assert any("cancelled: trace replay says 1" in e for e in errs)

    stats["cancelled"] = 1
    stats["goodput"] = 0.5
    errs = tr.check(r, stats, {"dropped": 0})
    assert any("goodput: recomputed 0.0" in e for e in errs)


def test_check_requires_serverstats():
    r = tr.audit(clean_lifecycle())
    assert any("serverStats" in e for e in tr.check(r, None, {}))


# ------------------------------------------------------------- file I/O


def test_load_reads_chrome_trace_and_jsonl(tmp_path):
    events = clean_lifecycle()
    chrome = tmp_path / "t.json"
    chrome.write_text(json.dumps({
        "displayTimeUnit": "ms",
        "traceEvents": [],
        "loramEvents": events,
        "otherData": {"clock": "tick", "dropped": 0},
        "serverStats": {"served": 1},
    }))
    got, stats, other = tr.load(str(chrome))
    assert got == events and stats == {"served": 1} and other["clock"] == "tick"

    jsonl = tmp_path / "t.jsonl"
    jsonl.write_text("".join(json.dumps(e) + "\n" for e in events))
    got, stats, other = tr.load(str(jsonl))
    assert got == events and stats is None


def test_cli_check_mode_on_disk_roundtrip(tmp_path, capsys):
    r = tr.audit(clean_lifecycle())
    path = tmp_path / "ok.json"
    path.write_text(json.dumps({
        "loramEvents": clean_lifecycle(),
        "otherData": {"clock": "tick", "dropped": 0},
        "serverStats": _stats_for(r),
    }))
    assert tr.main(["trace_report.py", "--check", str(path)]) == 0
    assert "bit-for-bit" in capsys.readouterr().out

    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({
        "loramEvents": [ev("DecodeStep", 0, row=0)],
        "otherData": {"dropped": 0},
        "serverStats": {},
    }))
    assert tr.main(["trace_report.py", "--check", str(bad)]) == 1


# ------------------------------------------------------------ schema sync


def test_event_schema_is_in_sync_between_rust_and_python():
    # the real gate CI runs: parse trace.rs + trace_report.py, diff kinds
    assert sync.main(["event_sync_check.py", str(REPO)]) == 0


def test_schema_parsers_see_all_twenty_four_kinds_with_fields():
    variants = sync.parse_rust_enum(str(REPO / "rust/src/obs/trace.rs"))
    assert len(variants) == 24
    assert [n for n, _ in variants] == list(tr.KINDS)
    by_name = dict(variants)
    assert by_name["Finish"] == ["req", "row", "tokens"]
    assert by_name["Preempt"] == ["req", "row", "tokens"]
    assert by_name["Cancel"] == ["req"]
    assert by_name["DeadlineMiss"] == ["req"]
    assert by_name["SessionRun"] == ["artifact", "h2d_ms", "exec_ms", "d2h_ms"]
    assert by_name["Fault"] == ["req", "row", "fault"]
    assert by_name["Retry"] == ["req", "attempt"]
    assert by_name["Failed"] == ["req", "tokens", "attempts"]
    assert by_name["Degrade"] == ["level"]
    assert by_name["Recover"] == []

"""loramlint suite tests (stdlib only — no jax, no cargo).

Each lint pass gets a firing fixture and a quiet fixture, the rustsrc
model gets lexer/test-span/annotation coverage, the ratchet baseline
gets a new-violation AND a stale-entry failure, and each contract-mirror
pair gets a drift fixture. The final test is the acceptance gate: the
real repo must scan clean against the committed baseline.
"""

import json
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[2]
sys.path.insert(0, str(REPO / "tools"))

from loramlint import contract_mirror, lock_discipline  # noqa: E402
from loramlint import panic_surface, report, result_hygiene  # noqa: E402
from loramlint import trace_coverage  # noqa: E402
from loramlint.cli import Context  # noqa: E402
from loramlint.rustsrc import RustFile, lex  # noqa: E402


def ctx_for(files, config=None, texts=None):
    """A Context over in-memory sources: `files` maps relpath -> rust
    source; `texts` maps relpath -> raw text for ctx.read()."""
    ctx = Context(str(REPO), {p: RustFile(p, s) for p, s in files.items()},
                  config or {})
    if texts:
        ctx._texts.update(texts)
    return ctx


# --------------------------------------------------------------- rustsrc


def test_lexer_ignores_strings_and_comments():
    toks = lex('let s = "x.unwrap()"; /* .expect( /* nested */ */ // panic!\n')
    idents = [t.text for t in toks if t.kind == "ident"]
    assert "unwrap" not in idents and "expect" not in idents
    assert [t.text for t in toks if t.kind == "str"] == ['"x.unwrap()"']


def test_lexer_lifetime_vs_char():
    toks = lex("fn f<'a>(x: &'a str) { let c = 'x'; }")
    kinds = {t.text: t.kind for t in toks if t.kind in ("lifetime", "char")}
    assert kinds["'a"] == "lifetime" and kinds["'x'"] == "char"


def test_cfg_test_spans_and_fn_extraction():
    rf = RustFile("x.rs", (
        "impl Server {\n"
        "    pub fn step(&mut self) { self.n += 1; }\n"
        "}\n"
        "#[cfg(test)]\n"
        "mod tests {\n"
        "    #[test]\n"
        "    fn t() { x.unwrap(); }\n"
        "}\n"
    ))
    assert not rf.is_test_line(2) and rf.is_test_line(7)
    quals = {f.qual: f.is_test for f in rf.fns}
    assert quals == {"Server::step": False, "t": True}


def test_allow_annotation_requires_reason():
    rf = RustFile("x.rs", (
        "fn a() { x.unwrap(); } // lint: allow(panic, \"boot-time only\")\n"
        "// lint: allow(panic)\n"
        "fn b() { y.unwrap(); }\n"
    ))
    assert rf.allow(1, "panic-surface")  # alias resolves, reason present
    assert rf.allow(3, "panic-surface") is None  # bare: does NOT suppress
    assert rf.bare_allow(3, "panic-surface")


# --------------------------------------------------------- panic-surface

HOT = {"hot_paths": ("hot.rs",)}


def test_panic_surface_fires_on_each_kind():
    src = (
        "fn f(v: &[u8]) -> u8 {\n"
        "    let a = v.first().unwrap();\n"
        "    let b = opt.expect(\"msg\");\n"
        "    if bad { panic!(\"no\"); }\n"
        "    v[0]\n"
        "}\n"
    )
    out = panic_surface.run(ctx_for({"hot.rs": src}, HOT))
    kinds = sorted(v.key.split("@")[0] for v in out)
    assert kinds == ["expect", "index", "panic", "unwrap"]


def test_panic_surface_quiet_on_clean_and_test_code():
    src = (
        "fn f(v: &[u8]) -> anyhow::Result<u8> {\n"
        "    let a = v.first().copied().unwrap_or(0);\n"
        "    v.get(1).copied().ok_or_else(|| anyhow::anyhow!(\"short\"))\n"
        "}\n"
        "#[cfg(test)]\n"
        "mod tests {\n"
        "    #[test] fn t() { assert_eq!(f(&[1][..]).unwrap(), 1); }\n"
        "}\n"
    )
    assert panic_surface.run(ctx_for({"hot.rs": src}, HOT)) == []


def test_panic_surface_allow_with_reason_suppresses():
    src = (
        "fn f() {\n"
        "    // lint: allow(panic, \"invariant: ladder validated above\")\n"
        "    let g = l.last().unwrap();\n"
        "    let h = l.last().unwrap(); // lint: allow(panic)\n"
        "}\n"
    )
    out = panic_surface.run(ctx_for({"hot.rs": src}, HOT))
    assert len(out) == 1 and out[0].line == 4
    assert "no reason" in out[0].msg


def test_panic_surface_scopes_to_hot_paths_only():
    src = "fn f() { x.unwrap(); }\n"
    assert panic_surface.run(ctx_for({"cold.rs": src}, HOT)) == []


# -------------------------------------------------------- result-hygiene


def test_result_hygiene_fires_in_scope_quiet_outside():
    src = "fn f() { let _ = fallible(); }\n"
    fires = result_hygiene.run(
        ctx_for({"rust/src/coordinator/x.rs": src}))
    assert [v.line for v in fires] == [1]
    quiet = result_hygiene.run(ctx_for({"rust/src/serve.rs": src}))
    assert quiet == []


def test_result_hygiene_named_discard_and_allow_are_quiet():
    src = (
        "fn f() {\n"
        "    let _released = fallible();\n"
        "    // lint: allow(result, \"best-effort cleanup\")\n"
        "    let _ = fallible();\n"
        "}\n"
    )
    assert result_hygiene.run(
        ctx_for({"rust/src/coordinator/x.rs": src})) == []


# ------------------------------------------------------- lock-discipline

LOCKS = {"lock_targets": ("l.rs",)}


def test_lock_guard_held_across_run_fires():
    src = (
        "impl G {\n"
        "    fn step(&self) {\n"
        "        let st = self.state.borrow_mut();\n"
        "        st.sess.run(rt);\n"
        "    }\n"
        "}\n"
    )
    out = lock_discipline.run(ctx_for({"l.rs": src}, LOCKS))
    assert len(out) == 1 and "held across `run(`" in out[0].msg


def test_lock_drop_and_block_scope_end_liveness():
    src = (
        "impl G {\n"
        "    fn a(&self) {\n"
        "        let st = self.state.borrow_mut();\n"
        "        drop(st);\n"
        "        self.sess.run(rt);\n"
        "    }\n"
        "    fn b(&self) {\n"
        "        { let st = self.state.borrow_mut(); st.tick(); }\n"
        "        self.sess.run(rt);\n"
        "    }\n"
        "    fn c(&self) {\n"
        "        self.state.borrow_mut().tick();\n"
        "        self.sess.run(rt);\n"
        "    }\n"
        "}\n"
    )
    assert lock_discipline.run(ctx_for({"l.rs": src}, LOCKS)) == []


def test_lock_order_inversion_fires_and_table_published():
    src = (
        "impl G {\n"
        "    fn ab(&self) { let a = self.a_lock.lock(); let b = self.b_lock.lock(); }\n"
        "    fn ba(&self) { let b = self.b_lock.lock(); let a = self.a_lock.lock(); }\n"
        "}\n"
    )
    ctx = ctx_for({"l.rs": src}, LOCKS)
    out = lock_discipline.run(ctx)
    assert any("inversion" in v.msg for v in out)
    table = ctx.artifacts["lock_order_table"]
    assert table["l.rs:G::ab"] == ["self.a_lock", "self.b_lock"]


def test_lock_plain_file_read_is_not_an_acquisition():
    src = "impl G { fn f(&self) { let n = file.read(buf); self.sess.run(rt); } }\n"
    assert lock_discipline.run(ctx_for({"l.rs": src}, LOCKS)) == []


# ------------------------------------------------------- trace-coverage

TRACE_RS = (
    'pub enum Event {\n'
    '    Admit { req: u64 },\n'
    '    Evict { row: usize },\n'
    '}\n'
    'pub const KINDS: &[&str] = &["Admit", "Evict"];\n'
)
TRACE_CFG = {
    "trace_required": (("s.rs", "Server", "admit", ("Admit",)),),
    "trace_rs": "t.rs",
}


def _trace_files(admit_body):
    return {
        "s.rs": f"impl Server {{ fn admit(&mut self) {{ {admit_body} }} }}\n",
        "t.rs": TRACE_RS,
    }


def test_trace_coverage_quiet_when_emitting():
    files = _trace_files(
        "emit(|| Event::Admit { req }); x.push(Event::Evict { row });")
    assert trace_coverage.run(ctx_for(files, TRACE_CFG)) == []


def test_trace_coverage_no_emit_and_missing_kind_fire():
    out = trace_coverage.run(
        ctx_for(_trace_files("self.rows += 1; let e = Event::Evict { row };"),
                TRACE_CFG))
    keys = {v.key.split("@")[0] for v in out}
    assert "no-emit" in keys


def test_trace_coverage_rename_detection():
    files = {
        "s.rs": "impl Server { fn admit_row(&mut self) { emit(|| Event::Admit { req }); emit(|| Event::Evict { row }); } }\n",
        "t.rs": TRACE_RS,
    }
    out = trace_coverage.run(ctx_for(files, TRACE_CFG))
    assert any(v.key == "missing-fn@Server::admit" for v in out)


def test_trace_coverage_kind_liveness():
    # Evict declared but never constructed; Ghost constructed but undeclared
    files = _trace_files("emit(|| Event::Admit { req }); emit(|| Event::Ghost { x });")
    out = trace_coverage.run(ctx_for(files, TRACE_CFG))
    keys = {v.key for v in out}
    assert "dead-kind@Evict" in keys and "unknown-kind@Ghost" in keys


# ------------------------------------------------------- contract-mirror

KV_OK = (
    "pub fn chunk_ladder(seq: usize) -> Vec<usize> {\n"
    "    let mut v = vec![16.min(seq), 64.min(seq), seq];\n"
    "    v.sort_unstable(); v.dedup(); v\n"
    "}\n"
    "pub const PAGED_BLOCK: usize = 8;\n"
    "pub fn paged_pool_blocks(b: usize, s: usize, block: usize) -> usize {\n"
    "    b * (s / block)\n"
    "}\n"
)
AOT_OK = (
    "def chunk_ladder(s):\n    return sorted({min(16, s), min(64, s), s})\n"
    "PAGED_BLOCK = 8\n"
    "def paged_pool_blocks(b, s, block=PAGED_BLOCK):\n"
    "    return b * (s // block)\n"
)


def _mirror_ctx(kv_src, aot_src, contracts):
    return ctx_for(
        {"rust/src/coordinator/kvcache.rs": kv_src},
        {"contracts": [c for c in contract_mirror.CONTRACTS
                       if c.name in contracts]},
        texts={"python/compile/aot.py": aot_src},
    )


def test_chunk_ladder_contract_drift_and_clean():
    assert contract_mirror.run(
        _mirror_ctx(KV_OK, AOT_OK, {"chunk-ladder"})) == []
    drifted = AOT_OK.replace("min(64, s)", "min(32, s)")
    out = contract_mirror.run(_mirror_ctx(KV_OK, drifted, {"chunk-ladder"}))
    assert len(out) == 1 and "drifted" in out[0].msg


def test_paged_geometry_contract_drift_on_const_and_formula():
    assert contract_mirror.run(
        _mirror_ctx(KV_OK, AOT_OK, {"paged-geometry"})) == []
    out = contract_mirror.run(_mirror_ctx(
        KV_OK.replace("PAGED_BLOCK: usize = 8", "PAGED_BLOCK: usize = 16"),
        AOT_OK, {"paged-geometry"}))
    assert any("PAGED_BLOCK drifted" in v.msg for v in out)
    out = contract_mirror.run(_mirror_ctx(
        KV_OK.replace("b * (s / block)", "b * s / block"),
        AOT_OK, {"paged-geometry"}))
    assert any("formula drifted" in v.msg for v in out)


def test_trace_schema_version_contract_drift():
    ctx = ctx_for({}, {"contracts": [
        c for c in contract_mirror.CONTRACTS
        if c.name == "trace-schema-version"]},
        texts={
            "rust/src/obs/export.rs":
                "pub const TRACE_SCHEMA_VERSION: u64 = 2;\n",
            "tools/trace_report.py": "TRACE_SCHEMA_VERSION = 1\n",
        })
    out = contract_mirror.run(ctx)
    assert len(out) == 1 and "writes 2" in out[0].msg


def test_event_kinds_contract_drift():
    trace = (
        'pub enum Event {\n    Admit { req: u64 },\n    Extra { x: u64 },\n}\n'
        'pub const KINDS: &[&str] = &["Admit", "Extra"];\n'
    )
    rep = 'KINDS = {\n    "Admit": ("req",),\n}\n'
    ctx = ctx_for({}, {"contracts": [
        c for c in contract_mirror.CONTRACTS if c.name == "event-kinds"]},
        texts={"rust/src/obs/trace.rs": trace,
               "tools/trace_report.py": rep})
    out = contract_mirror.run(ctx)
    assert any("only in trace.rs: ['Extra']" in v.msg for v in out)


def test_metrics_keys_contract_flags_unproduced_consumer_key():
    texts = {
        "rust/src/serve.rs": 'm.set_counter("serve.served", 1);\n',
        "rust/src/coordinator/kvcache.rs": "",
        "rust/src/coordinator/speculative.rs": "",
        "rust/benches/bench_main.rs":
            'let a = m.counter("serve.served"); let b = m.counter("serve.gone");\n',
        "rust/src/coordinator/experiments/tab8.rs": "",
        "tools/trace_report.py": "",
        "rust/src/main.rs": "",
    }
    ctx = ctx_for({}, {"contracts": [
        c for c in contract_mirror.CONTRACTS if c.name == "metrics-keys"]},
        texts=texts)
    out = contract_mirror.run(ctx)
    assert len(out) == 1 and "serve.gone" in out[0].msg


def test_workload_scenarios_contract_drift_and_clean():
    wl = 'pub const SCENARIOS: &[&str] = &["steady", "bursty-heavytail"];\n'
    gen = 'SCENARIOS = [\n    "steady",\n    "bursty-heavytail",\n]\n'

    def mkctx(w, g):
        return ctx_for({}, {"contracts": [
            c for c in contract_mirror.CONTRACTS
            if c.name == "workload-scenarios"]},
            texts={"rust/src/workload.rs": w, "tools/workload_gen.py": g})

    assert contract_mirror.run(mkctx(wl, gen)) == []
    drift = gen.replace('"bursty-heavytail"', '"bursty"')
    out = contract_mirror.run(mkctx(wl, drift))
    assert len(out) == 1 and "catalog drifted" in out[0].msg
    # a reorder is drift too: the order is part of the contract
    swap = 'SCENARIOS = [\n    "bursty-heavytail",\n    "steady",\n]\n'
    out = contract_mirror.run(mkctx(wl, swap))
    assert len(out) == 1 and "catalog drifted" in out[0].msg


def test_chaos_contract_pairs_drift_and_clean():
    chaos = (
        'pub const FAULT_KINDS: &[&str] = &["decode-transient", "admit-fail"];\n'
        'pub const CHAOS_SCENARIOS: &[&str] = &["fault-storm", "device-loss"];\n'
    )
    gen = (
        'FAULT_KINDS = [\n    "decode-transient",\n    "admit-fail",\n]\n'
        'CHAOS_SCENARIOS = [\n    "fault-storm",\n    "device-loss",\n]\n'
    )

    def mkctx(c, g, name):
        return ctx_for({}, {"contracts": [
            x for x in contract_mirror.CONTRACTS if x.name == name]},
            texts={"rust/src/chaos.rs": c, "tools/chaos_gen.py": g})

    for name in ("chaos-scenarios", "fault-kinds"):
        assert contract_mirror.run(mkctx(chaos, gen, name)) == []
    drift = gen.replace('"device-loss"', '"device-gone"')
    out = contract_mirror.run(mkctx(chaos, drift, "chaos-scenarios"))
    assert len(out) == 1 and "catalog drifted" in out[0].msg
    # kind order is load-bearing: a plan's kind_ix indexes the table on
    # both sides, so a reorder silently re-aims every scheduled fault
    swap = gen.replace(
        '"decode-transient",\n    "admit-fail"',
        '"admit-fail",\n    "decode-transient"')
    out = contract_mirror.run(mkctx(chaos, swap, "fault-kinds"))
    assert len(out) == 1 and "taxonomy drifted" in out[0].msg


def test_trace_coverage_required_table_covers_chaos_lifecycle():
    # §2j events must stay pinned to their emission sites, like §2i's
    required = {
        (impl, fn): kinds for _, impl, fn, kinds in trace_coverage.REQUIRED
    }
    assert {"Fault", "Retry", "Failed"} <= set(required[("Server", "fault_row")])
    assert {"Degrade", "Recover"} <= set(required[("Server", "set_health")])
    assert "Failed" in required[("Server", "fail_everything")]
    assert "Failed" in required[("Server", "fail_queue")]


def test_trace_coverage_required_table_covers_slo_lifecycle():
    # the §2i events must stay pinned to their emission sites: dropping
    # one from REQUIRED would let a refactor silently un-trace it
    required = {
        (impl, fn): kinds for _, impl, fn, kinds in trace_coverage.REQUIRED
    }
    assert "Preempt" in required[("Server", "preempt")]
    assert "Cancel" in required[("Server", "cancel_expired")]
    assert "DeadlineMiss" in required[("Server", "step")]
    assert "Preempt" in required[("Server", "step")], \
        "the forced-admission pool-pressure requeue emits Preempt from step"
    assert "Enqueue" in required[("Server", "enqueue_slo")]


# ------------------------------------------------------ ratchet baseline


def _v(key, line=1, file="a.rs", rule="panic-surface"):
    return report.Violation(rule, file, line, key, f"msg {key}")


def test_baseline_ratchet_new_and_stale_both_fail(tmp_path):
    path = tmp_path / "baseline.json"
    report.write_baseline(str(path), [_v("k1"), _v("k2")])
    doc = report.load_baseline(str(path))
    # identical scan: clean
    new, stale = report.check_against_baseline([_v("k1"), _v("k2")], doc)
    assert new == [] and stale == []
    # one extra site: NEW violation
    new, stale = report.check_against_baseline(
        [_v("k1"), _v("k2"), _v("k3", line=9)], doc)
    assert [v.key for v in new] == ["k3"] and new[0].line == 9 and stale == []
    # one fixed site: STALE baseline entry (ratchet must shrink)
    new, stale = report.check_against_baseline([_v("k1")], doc)
    assert new == [] and len(stale) == 1 and "k2" in stale[0]


def test_baseline_counts_duplicate_lines(tmp_path):
    path = tmp_path / "baseline.json"
    report.write_baseline(str(path), [_v("dup", 1), _v("dup", 5)])
    doc = report.load_baseline(str(path))
    # same count, different lines: still clean (content-keyed, not line-keyed)
    new, stale = report.check_against_baseline(
        [_v("dup", 2), _v("dup", 7)], doc)
    assert new == [] and stale == []
    # third copy of the same line: new
    new, _ = report.check_against_baseline(
        [_v("dup", 2), _v("dup", 7), _v("dup", 8)], doc)
    assert len(new) == 1


# ---------------------------------------------------------- acceptance


def test_real_repo_scans_clean_against_committed_baseline():
    res = subprocess.run(
        [sys.executable, str(REPO / "tools/loramlint/__main__.py"),
         "rust/src", "--json"],
        cwd=str(REPO), capture_output=True, text=True)
    doc = json.loads(res.stdout)
    assert res.returncode == 0, (doc["new_violations"], doc["stale_baseline"])
    assert doc["new_violations"] == [] and doc["stale_baseline"] == []
    assert len(doc["scanned_files"]) > 40


def test_repo_hot_paths_have_no_unwrap_expect_in_serve_and_kvcache():
    # the PR 8 burn-down acceptance: serve.rs + kvcache.rs carry zero
    # non-test unwrap/expect/panic! (pre-PR scan had 6)
    ctx = Context(str(REPO), {})
    for rel in ("rust/src/serve.rs", "rust/src/coordinator/kvcache.rs"):
        assert ctx.rust_file(rel) is not None
    out = panic_surface.run(ctx)
    bad = [v for v in out
           if v.file in ("rust/src/serve.rs", "rust/src/coordinator/kvcache.rs")
           and v.key.split("@")[0] in ("unwrap", "expect", "panic")]
    assert bad == []

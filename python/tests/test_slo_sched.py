"""SLO scheduler tick-model tests (stdlib only — no jax, no cargo).

Three layers, mirroring DESIGN.md Sec 2i:

1. `tools/workload_gen.py` golden pins — the PCG64-DXSM mirror and the
   first requests of every scenario, the exact values
   `rust/src/util/rng.rs` / `rust/src/workload.rs` assert in their unit
   tests, so the adversarial streams are bit-identical cross-language.
2. `tools/slo_sim.py` scenario pre-validation — the same scheduler
   scenarios the `serve.rs` SimEngine tests assert (preempt-and-requeue
   conservation, deadline-storm cancellation, priority admission order,
   late-finish misses, fairness cap, SLO-beats-FIFO A/B), checked
   against the Python tick model with the same expected numbers.
3. Conservation — every model stream must pass the full
   `tools/trace_report.py` law suite, --check included, bit-for-bit.
"""

import importlib.util
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[2]
sys.path.insert(0, str(REPO / "tools"))


def _load(name, rel):
    spec = importlib.util.spec_from_file_location(name, REPO / rel)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


wg = _load("workload_gen", "tools/workload_gen.py")
sim = _load("slo_sim", "tools/slo_sim.py")
tr = _load("trace_report", "tools/trace_report.py")


def req(max_new, priority="normal", deadline=None, adapter=None):
    return {
        "arrival_tick": 0,
        "prompt_len": 1,
        "max_new": max_new,
        "priority": priority,
        "deadline_ticks": deadline,
        "adapter_ix": adapter,
    }


def audit_ok(srv):
    """Full conservation suite over the model's stream: law replay plus
    the bit-for-bit --check against the embedded serverStats."""
    report = tr.audit(srv.events)
    assert report["violations"] == [], report["violations"]
    doc = srv.trace_doc()
    errs = tr.check(report, doc["serverStats"], doc["otherData"])
    assert errs == [], errs
    return report


# ------------------------------------------------- workload golden pins


def test_rng_matches_the_rust_golden_values():
    # pinned on the Rust side by rng.rs::matches_the_python_mirror_golden_values
    r = wg.Rng(7)
    assert [r.next_u64() for _ in range(4)] == [
        11819415725983595385,
        5343028139622295922,
        12185485406386585458,
        10788631124621038257,
    ]
    r = wg.Rng(0)
    assert [r.next_u64() for _ in range(2)] == [
        546717224284700557,
        9027004767291937668,
    ]
    r = wg.Rng(9)
    assert [r.below(8) for _ in range(6)] == [1, 0, 6, 7, 1, 1]


def test_scenario_streams_match_the_rust_goldens():
    # pinned on the Rust side by
    # workload.rs::generated_streams_match_the_python_mirror_goldens
    def gold(s):
        return [
            (r["arrival_tick"], r["prompt_len"], r["max_new"], r["priority"],
             r["deadline_ticks"], r["adapter_ix"])
            for r in wg.generate(s, 4, 9)
        ]

    assert gold("steady") == [
        (0, 9, 4, "normal", None, None),
        (1, 14, 7, "normal", None, None),
        (2, 9, 4, "normal", None, None),
        (3, 10, 4, "normal", None, None),
    ]
    assert gold("bursty-heavytail") == [
        (1, 14, 8, "high", 12, None),
        (1, 20, 6, "normal", None, None),
        (1, 8, 14, "low", None, None),
        (6, 11, 4, "normal", None, None),
    ]
    assert gold("adapter-skew") == [
        (1, 14, 7, "normal", None, 0),
        (2, 10, 2, "normal", None, 0),
        (2, 10, 3, "normal", None, 0),
        (2, 14, 6, "normal", None, 0),
    ]
    assert gold("deadline-storm") == [
        (0, 9, 2, "normal", 5, None),
        (0, 15, 2, "normal", 2, None),
        (0, 10, 2, "normal", 4, None),
        (0, 13, 3, "normal", 2, None),
    ]
    assert gold("rejection-storm") == [
        (0, 150, 4, "normal", None, None),
        (0, 158, 1, "normal", None, None),
        (0, 103, 2, "normal", None, None),
        (0, 76, 3, "normal", None, None),
    ]


def test_scenarios_are_deterministic_and_well_formed():
    # mirror of workload.rs::scenarios_are_deterministic_and_well_formed
    for s in wg.SCENARIOS:
        a = wg.generate(s, 64, 9)
        assert a == wg.generate(s, 64, 9), s
        assert a != wg.generate(s, 64, 10), s
        last = 0
        for r in a:
            assert r["arrival_tick"] >= last, f"{s} arrivals must be monotonic"
            last = r["arrival_tick"]
            assert r["prompt_len"] >= 1 and r["max_new"] >= 1


def test_unknown_scenario_raises_with_the_catalog():
    try:
        wg.generate("nope", 1, 0)
    except ValueError as e:
        assert "steady" in str(e)
    else:
        raise AssertionError("unknown scenario must raise")


# --------------------------------------- tick-model scenario pre-checks


def test_preempt_and_requeue_conserves_every_token():
    # mirror of serve.rs::preempted_request_streams_byte_identical…: a
    # Low victim loses 2 tokens to a High arrival, re-runs from scratch,
    # and the audit conserves the discarded life
    srv = sim.SimServer(1, slo=True)
    low = srv.enqueue(req(6, "low"))
    assert srv.step() == [] and srv.step() == []  # 2 tokens sampled
    vip = srv.enqueue(req(2, "high"))
    done = srv.drain()
    assert [d["id"] for d in done] == [vip, low], "vip overtakes the victim"
    assert srv.preempted == 1
    assert srv.total_tokens == 2 + 2 + 6  # discarded + vip + re-run
    a = audit_ok(srv)
    assert a["preempted_tokens"] == 2
    assert len(a["ttft_ticks"]) == 2, "TTFT recorded once per request"


def test_deadline_storm_cancels_only_expired_without_row_leaks():
    # mirror of serve.rs::deadline_storm_cancels_only_expired…
    srv = sim.SimServer(2, slo=True)
    for _ in range(2):
        srv.enqueue(req(10))                      # rows occupied
    doomed = [srv.enqueue(req(2, deadline=1)) for _ in range(4)]
    patient = [srv.enqueue(req(2, deadline=100)) for _ in range(2)]
    done = srv.drain()
    assert srv.cancelled == 4 and srv.served == 4
    assert srv.deadline_misses == 0 and srv.rejected == 0
    served_ids = {d["id"] for d in done}
    assert served_ids.isdisjoint(doomed) and set(patient) <= served_ids
    assert srv.free_rows() == 2, "rows leaked"
    assert srv.goodput() == 4 / 8
    a = audit_ok(srv)
    assert a["cancelled"] == 4


def test_priority_classes_admit_in_order_and_equals_never_preempt():
    # mirror of serve.rs::priority_classes_admit_in_order…: strict-
    # inequality preemption means Normal never evicts Normal
    srv = sim.SimServer(1, slo=True)
    a = srv.enqueue(req(2, "low"))
    b = srv.enqueue(req(2, "normal"))
    c = srv.enqueue(req(2, "high"))
    d = srv.enqueue(req(2, "normal"))
    done = srv.drain()
    # the first admission already sees the whole queue, so the High entry
    # goes first, FIFO within the Normal class, Low last — and since no
    # higher class ever *waits* behind a live row, nothing is preempted
    assert [x["id"] for x in done] == [c, b, d, a]
    assert srv.preempted == 0
    audit_ok(srv)


def test_late_finish_records_a_deadline_miss_and_goodput_reflects_it():
    # mirror of serve.rs::late_finish_records_deadline_miss…
    srv = sim.SimServer(1, slo=True)
    srv.enqueue(req(2, deadline=50))
    srv.drain()
    slow = srv.enqueue(req(5, deadline=2))  # needs 5 ticks, has 2
    srv.drain()
    assert srv.served == 2 and srv.cancelled == 0
    assert srv.deadline_misses == 1
    assert srv.goodput() == 1 / 2
    a = audit_ok(srv)
    assert a["deadline_misses"] == 1
    # the miss belongs to the slow request
    assert [e["req"] for e in srv.events if e["kind"] == "DeadlineMiss"] == [slow]


def test_adapter_fairness_cap_bounds_the_hot_lane():
    # mirror of serve.rs::adapter_fairness_cap_holds_under_ten_to_one_skew
    reqs = wg.generate("adapter-skew", 40, 11)

    def worst_cold_ttft(fair_rows):
        srv = sim.SimServer(4, slo=True, fair_rows=fair_rows)
        sim.run_workload(srv, reqs)
        audit_ok(srv)
        # replay peak concurrent hot-lane rows from the event stream
        hot_ids = {
            i for i, r in enumerate(reqs) if r["adapter_ix"] == 0
        }
        occ, peak = {}, 0
        for e in srv.events:
            if e["kind"] == "Admit":
                occ[e["row"]] = e["req"]
            elif e["kind"] in ("Finish", "Preempt"):
                occ.pop(e["row"], None)
            peak = max(peak, sum(1 for r in occ.values() if r in hot_ids))
        cold = [
            t for rid, (_, t) in srv.req_ttft.items()
            if reqs[rid]["adapter_ix"] == 1
        ]
        return peak, max(cold)

    capped_peak, capped_cold = worst_cold_ttft(2)
    free_peak, free_cold = worst_cold_ttft(None)
    assert capped_peak <= 2, "hot lane exceeded the row cap"
    assert free_peak == 4, "uncapped run must fill the batch with hot rows"
    assert capped_cold < free_cold, (
        f"cap should shield the cold lane: {capped_cold} vs {free_cold}"
    )


def test_slo_beats_fifo_on_goodput_and_high_priority_ttft():
    # the BENCH_serve A/B headline, pre-validated in the tick model
    fifo, slo = sim.run_ab("bursty-heavytail", 48, 9, 4)
    audit_ok(fifo)
    audit_ok(slo)
    assert fifo.preempted == 0, "FIFO must never preempt"
    assert slo.preempted > 0, "the scenario must actually exercise preemption"
    assert slo.goodput() > fifo.goodput()
    assert sim.hi_ttft_p95(slo) < sim.hi_ttft_p95(fifo)


def test_workload_run_collapses_idle_gaps():
    # arrivals into an idle server enqueue immediately: the clock only
    # advances while work exists (mirror of workload.rs::run's guard —
    # without it the arrival wait would spin forever)
    srv = sim.SimServer(2, slo=True)
    reqs = [dict(req(1), arrival_tick=100), dict(req(1), arrival_tick=200)]
    done = sim.run_workload(srv, reqs)
    assert len(done) == 2
    assert srv.ticks < 100, "idle ticks must not be burned"
    audit_ok(srv)


def test_every_scenario_stream_passes_conservation_under_both_policies():
    # mirror of workload.rs::workload_through_slo_server_passes…,
    # widened to the whole catalog × {fifo, slo}
    for scenario in wg.SCENARIOS:
        reqs = wg.generate(scenario, 24, 3)
        for slo in (False, True):
            srv = sim.SimServer(4, slo=slo)
            done = sim.run_workload(srv, reqs)
            a = audit_ok(srv)
            assert a["enqueued"] == 24, scenario
            assert a["finished"] == srv.served, scenario
            assert a["tokens"] == srv.total_tokens, scenario
            assert len(done) + srv.cancelled == 24, (
                f"{scenario}: every arrival must be served or cancelled"
            )


def test_ab_cli_gate_exits_zero_on_the_headline_scenario(capsys):
    rc = sim.main(["slo_sim.py", "--ab", "bursty-heavytail", "-n", "48",
                   "--seed", "9"])
    out = capsys.readouterr().out
    assert rc == 0, out
    assert "SLO beats FIFO" in out


def test_trace_doc_roundtrips_through_trace_report_check(tmp_path):
    srv = sim.SimServer(4, slo=True)
    sim.run_workload(srv, wg.generate("deadline-storm", 24, 5))
    path = tmp_path / "slo.json"
    import json

    path.write_text(json.dumps(srv.trace_doc()))
    assert tr.main(["trace_report.py", "--check", str(path)]) == 0

"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps the shape/scale space; fixed-seed cases pin the numerics.
Tolerances are f32 matmul accumulation tolerances (kernels accumulate in
f32 scratch, oracles accumulate via XLA dot — bit-identical is not expected).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.lora_matmul import lora_matmul
from compile.kernels.masked_lora import masked_lora_matmul
from compile.kernels.nf4 import nf4_dequant_matmul
from compile.kernels.tiling import fit_tile, fit_tile_multiple

TOL = dict(rtol=2e-4, atol=2e-4)


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


# ---------------------------------------------------------------------------
# tiling
# ---------------------------------------------------------------------------

@given(dim=st.integers(1, 512), target=st.integers(1, 256))
@settings(max_examples=200, deadline=None)
def test_fit_tile_divides(dim, target):
    t = fit_tile(dim, target)
    assert 1 <= t <= max(dim, 1)
    assert dim % t == 0
    assert t <= max(target, 1) or t == 1


@given(dim=st.integers(1, 64).map(lambda k: k * 16),
       target=st.integers(16, 256))
@settings(max_examples=100, deadline=None)
def test_fit_tile_multiple_divides(dim, target):
    t = fit_tile_multiple(dim, target, 16)
    assert dim % t == 0 and t % 16 == 0


# ---------------------------------------------------------------------------
# lora_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,m,n,r,scale", [
    (8, 16, 24, 4, 1.0),
    (16, 32, 48, 8, 2.0),
    (64, 64, 160, 8, 0.5),   # non-pow2 n (tiny d_ff)
    (1, 16, 16, 1, 3.0),
])
def test_lora_matmul_fixed(s, m, n, r, scale):
    rng = np.random.default_rng(42)
    x, w = _rand(rng, s, m), _rand(rng, m, n)
    a, b = _rand(rng, m, r), _rand(rng, r, n)
    got = lora_matmul(x, w, a, b, scale=scale, bs=8, bn=16, bm=16)
    want = ref.lora_matmul_ref(x, w, a, b, scale)
    np.testing.assert_allclose(got, want, **TOL)


@given(s=st.sampled_from([1, 4, 8, 32]),
       m=st.sampled_from([8, 16, 48, 64]),
       n=st.sampled_from([8, 16, 80, 128]),
       r=st.sampled_from([1, 2, 8]),
       scale=st.floats(0.0, 4.0),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_lora_matmul_sweep(s, m, n, r, scale, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, s, m), _rand(rng, m, n)
    a, b = _rand(rng, m, r), _rand(rng, r, n)
    got = lora_matmul(x, w, a, b, scale=scale, bs=16, bn=32, bm=16)
    want = ref.lora_matmul_ref(x, w, a, b, scale)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_lora_matmul_zero_b_is_base_matmul():
    """LoRA invariant: with b = 0 the fused kernel equals the base matmul."""
    rng = np.random.default_rng(7)
    x, w, a = _rand(rng, 8, 32), _rand(rng, 32, 64), _rand(rng, 32, 8)
    b = jnp.zeros((8, 64), jnp.float32)
    got = lora_matmul(x, w, a, b, scale=2.0)
    np.testing.assert_allclose(got, x @ w, **TOL)


# ---------------------------------------------------------------------------
# masked_lora_matmul
# ---------------------------------------------------------------------------

@given(s=st.sampled_from([4, 8]), m=st.sampled_from([16, 32]),
       n=st.sampled_from([16, 64]), r=st.sampled_from([2, 8]),
       density=st.floats(0.0, 1.0), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_masked_lora_sweep(s, m, n, r, density, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, s, m), _rand(rng, m, n)
    a, b = _rand(rng, m, r), _rand(rng, r, n)
    mask = jnp.asarray(rng.random((m, n)) < density, jnp.float32)
    wp = w * mask
    got = masked_lora_matmul(x, wp, a, b, mask, scale=1.5, bs=8, bn=16, bm=16)
    want = ref.masked_lora_matmul_ref(x, wp, a, b, mask, 1.5)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_masked_lora_full_mask_equals_dense():
    """M = 1 everywhere must reduce to the dense fused kernel."""
    rng = np.random.default_rng(3)
    x, w = _rand(rng, 8, 32), _rand(rng, 32, 48)
    a, b = _rand(rng, 32, 4), _rand(rng, 4, 48)
    ones = jnp.ones((32, 48), jnp.float32)
    got = masked_lora_matmul(x, w, a, b, ones, scale=2.0)
    want = ref.lora_matmul_ref(x, w, a, b, 2.0)
    np.testing.assert_allclose(got, want, **TOL)


def test_masked_lora_zero_mask_kills_everything():
    """M = 0 everywhere: pruned base (zeros) + fully-masked update = 0."""
    rng = np.random.default_rng(4)
    x = _rand(rng, 8, 32)
    zeros = jnp.zeros((32, 48), jnp.float32)
    a, b = _rand(rng, 32, 4), _rand(rng, 4, 48)
    got = masked_lora_matmul(x, zeros, a, b, zeros, scale=2.0)
    np.testing.assert_allclose(got, jnp.zeros((8, 48)), atol=1e-6)


# ---------------------------------------------------------------------------
# NF4
# ---------------------------------------------------------------------------

def test_nf4_quantize_roundtrip_error_bounded():
    """Blockwise NF4: |w - dq(q(w))| <= absmax * max codebook gap / 2."""
    rng = np.random.default_rng(5)
    w = _rand(rng, 32, 128)
    codes, absmax = ref.nf4_quantize_ref(w, 16)
    wd = ref.nf4_dequant_ref(codes, absmax, 16)
    gaps = np.diff(np.asarray(ref.NF4_CODEBOOK))
    bound = np.repeat(np.asarray(absmax), 16, axis=1) * (gaps.max() / 2 + 1e-6)
    assert np.all(np.abs(np.asarray(wd - w)) <= bound)


def test_nf4_extremes_are_exact():
    """Block extreme |max| elements map to codes 0/15 and round-trip exactly."""
    w = jnp.asarray([[1.0] + [0.0] * 15, [-2.0] + [0.5] * 15], jnp.float32)
    codes, absmax = ref.nf4_quantize_ref(w, 16)
    wd = ref.nf4_dequant_ref(codes, absmax, 16)
    assert float(wd[0, 0]) == pytest.approx(1.0)
    assert float(wd[1, 0]) == pytest.approx(-2.0)


@given(s=st.sampled_from([4, 8]), m=st.sampled_from([16, 32]),
       n=st.sampled_from([32, 64, 160]), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_nf4_dequant_matmul_sweep(s, m, n, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, s, m), _rand(rng, m, n)
    codes, absmax = ref.nf4_quantize_ref(w, 16)
    got = nf4_dequant_matmul(x, codes, absmax, block=16, bs=8, bn=32, bm=16)
    want = ref.nf4_dequant_matmul_ref(x, codes, absmax, 16)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_nf4_quant_codes_in_range():
    rng = np.random.default_rng(6)
    w = _rand(rng, 16, 64) * 10
    codes, absmax = ref.nf4_quantize_ref(w, 16)
    c = np.asarray(codes)
    assert c.min() >= 0 and c.max() <= 15
    assert np.all(np.asarray(absmax) >= 0)


@given(m=st.integers(1, 8), nb=st.integers(1, 6),
       block=st.sampled_from([8, 16, 32]),
       scale=st.floats(1e-3, 10.0),
       zero_block=st.booleans(),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=50, deadline=None)
def test_nf4_quantize_roundtrip_invariants(m, nb, block, scale, zero_block,
                                           seed):
    """Property sweep mirroring rust/src/quant.rs's invariants, so the
    QLoRAM path is pinned by laws, not only golden values:
    codes always index the 16-entry codebook, absmax is exactly the
    blockwise max |w|, and quantize∘dequantize is idempotent (requantising
    the dequantised matrix reproduces codes and absmax bit-for-bit)."""
    rng = np.random.default_rng(seed)
    w = np.asarray(rng.normal(size=(m, nb * block)) * scale, np.float32)
    if zero_block:
        w[0, :block] = 0.0  # all-zero blocks must round-trip too
    w = jnp.asarray(w)
    codes, absmax = ref.nf4_quantize_ref(w, block)
    assert codes.dtype == jnp.int32
    assert int(codes.min()) >= 0 and int(codes.max()) < 16
    want = np.abs(np.asarray(w).reshape(m, nb, block)).max(-1)
    np.testing.assert_array_equal(np.asarray(absmax), want)
    wd = ref.nf4_dequant_ref(codes, absmax, block)
    codes2, absmax2 = ref.nf4_quantize_ref(wd, block)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(codes2))
    np.testing.assert_array_equal(np.asarray(absmax), np.asarray(absmax2))

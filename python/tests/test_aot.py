"""AOT suite consistency: artifact specs line up with the model's canonical
parameter layout (the same invariants the Rust runtime relies on)."""

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M
from compile.configs import PRESETS, pruned_config


def test_smoke_suite_names_unique_and_complete():
    arts = aot.build_suite("smoke")
    names = [a.name for a in arts]
    assert len(names) == len(set(names))
    for required in ["pretrain_tiny", "sft_tiny", "sft_tiny_m", "sft_tiny_q",
                     "eval_tiny", "logits_tiny", "gradimp_tiny",
                     "pretrain_tiny_m", "sft_tiny_p50_q",
                     "logits_tiny_pallas", "logits_tiny_jnp"]:
        assert required in names, required


def test_std_suite_covers_experiment_configs():
    names = [a.name for a in aot.build_suite("std")]
    # fig3/4 + tab1-3 families
    for n in ["sft_l7b", "sft_l13b", "sft_l13b_m", "sft_l13b_p65",
              "pretrain_l13b_p65", "pretrain_l13b_m",
              # fig7/8 sweep
              "sft_l70b_p65_q", "sft_l70b_p75_q", "sft_l70b_p85_q",
              "sft_l70b_p95_q",
              # llama-3.1 family
              "sft_l8b", "sft_l70b3", "sft_l70b3_p85_q",
              # e2e
              "pretrain_e2e100m", "eval_e2e100m"]:
        assert n in names, n


def test_sft_artifact_input_order_is_canonical():
    """The Rust runtime Session depends on this exact flat-input convention:
    step, lr, tokens, loss_mask, params, [quant], [masks], lora, m, v."""
    art = aot.sft_artifact(PRESETS["tiny"], quantized=True, b=2, s=16)
    names = [n for n, _ in art.in_specs]
    assert names[:4] == ["step", "lr", "tokens", "loss_mask"]
    pn = art.extra["param_names"]
    qn = art.extra["quant_names"]
    ln = art.extra["lora_names"]
    i = 4
    assert names[i:i + len(pn)] == pn
    i += len(pn)
    assert names[i:i + len(qn)] == qn
    i += len(qn)
    assert names[i:i + len(ln)] == ln
    i += len(ln)
    assert names[i:i + len(ln)] == ["adam_m." + n for n in ln]
    i += len(ln)
    assert names[i:i + len(ln)] == ["adam_v." + n for n in ln]
    # outputs: loss then new state in lora order
    assert art.out_names[0] == "loss"
    assert art.out_names[1:1 + len(ln)] == ["new." + n for n in ln]


def test_quantized_artifact_drops_f32_projections():
    art = aot.sft_artifact(PRESETS["tiny"], quantized=True, b=2, s=16)
    names = [n for n, _ in art.in_specs]
    assert "l0.wq" not in names
    assert "l0.wq.codes" in names and "l0.wq.absmax" in names
    # embeddings / norms / lm_head stay f32
    assert "embed" in names and "lm_head" in names and "l0.attn_norm" in names


def test_pruned_cfg_plan_shapes_flow_into_artifact():
    cfg = pruned_config(PRESETS["tiny"], 0.5)
    art = aot.sft_artifact(cfg, b=2, s=16)
    # a pruned middle layer's wq input is narrower than the full one
    full = PRESETS["tiny"]
    mid = 1  # tiny protects first 2? n_layers=2 -> protect 2 first, 1 last
    # find any projection whose shape shrank
    shrunk = False
    for (n, spec) in art.in_specs:
        if n.endswith(".w_gate"):
            li = int(n.split(".")[0][1:])
            h, kv, ff = cfg.layer_shapes(li)
            assert list(spec.shape) == [cfg.d_model, ff]
            if ff < full.d_ff:
                shrunk = True
    assert shrunk or cfg.param_count() == full.param_count()


def test_nf4_block_divides_every_quantized_dim():
    """Only configs that actually receive _q artifacts must satisfy the
    block-alignment constraint (l8b's head_dim 28 never quantises)."""
    quantized = [("tiny", 0.5), ("l70b", 0.65), ("l70b", 0.75),
                 ("l70b", 0.85), ("l70b", 0.95), ("l70b3", 0.85)]
    for name, ratio in quantized:
        p = pruned_config(PRESETS[name], ratio)
        for i in range(p.n_layers):
            for k, (m, n) in M.layer_proj_shapes(p, i).items():
                assert n % aot.NF4_BLOCK == 0, (name, ratio, i, k, n)


def test_eval_artifact_reports_per_sequence():
    art = aot.eval_artifact(PRESETS["tiny"], b=3, s=16)
    outs = {o: None for o in art.out_names}
    assert set(outs) == {"nll_sum", "tok_count"}


def test_suites_register_decode_artifact_pair():
    """`python -m compile.aot --list`-style smoke check: the decode pair is
    present wherever a logits artifact serves decoding."""
    for suite in ("smoke", "std"):
        names = [a.name for a in aot.build_suite(suite)]
        assert "decode_prefill_tiny" in names or suite == "std"
        for n in names:
            if n.startswith("decode_prefill_"):
                assert n.replace("decode_prefill_", "decode_step_") in names
    smoke = [a.name for a in aot.build_suite("smoke")]
    assert "decode_prefill_tiny" in smoke and "decode_step_tiny" in smoke


def test_decode_step_artifact_declares_cache_donation():
    """Input order tokens, pos, params, lora, caches; every cache output
    donates onto its own input slot and is zero-init-able."""
    cfg = PRESETS["tiny"]
    art = aot.decode_step_artifact(cfg, b=2, s=16)
    names = [n for n, _ in art.in_specs]
    assert names[:2] == ["tokens", "pos"]
    pn, ln, cn = (art.extra["param_names"], art.extra["lora_names"],
                  art.extra["cache_names"])
    i = 2
    assert names[i:i + len(pn)] == pn
    i += len(pn)
    assert names[i:i + len(ln)] == ln
    i += len(ln)
    assert names[i:] == cn
    assert art.extra["state_bindings"] == {"new." + n: n for n in cn}
    assert art.extra["state_zero_init"] == cn
    assert art.out_names == ["logits"] + ["new." + n for n in cn]
    # cache shapes: (B, S, kv_i, hd), per-layer
    specs = dict(art.in_specs)
    for li in range(cfg.n_layers):
        _, kv, _ = cfg.layer_shapes(li)
        assert list(specs[f"cache_k.l{li}"].shape) == \
            [2, 16, kv, cfg.head_dim]
    # abstract eval: logits (B, V), cache outputs mirror cache inputs
    outs = jax.eval_shape(art.fn, *[s for _, s in art.in_specs])
    assert list(outs[0].shape) == [2, cfg.vocab_size]
    for o, n in zip(outs[1:], cn):
        assert list(o.shape) == list(specs[n].shape), n


def test_decode_prefill_artifact_is_single_row():
    cfg = PRESETS["tiny"]
    art = aot.decode_prefill_artifact(cfg, b=2, s=16)
    specs = dict(art.in_specs)
    assert list(specs["tokens"].shape) == [1, 16]
    assert list(specs["last_pos"].shape) == []
    assert list(specs["row_onehot"].shape) == [2]
    assert art.extra["state_bindings"] == \
        {"new." + n: n for n in art.extra["cache_names"]}
    outs = jax.eval_shape(art.fn, *[s for _, s in art.in_specs])
    assert list(outs[0].shape) == [1, cfg.vocab_size]

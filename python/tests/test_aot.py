"""AOT suite consistency: artifact specs line up with the model's canonical
parameter layout (the same invariants the Rust runtime relies on)."""

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M
from compile.configs import PRESETS, pruned_config


def test_smoke_suite_names_unique_and_complete():
    arts = aot.build_suite("smoke")
    names = [a.name for a in arts]
    assert len(names) == len(set(names))
    for required in ["pretrain_tiny", "sft_tiny", "sft_tiny_m", "sft_tiny_q",
                     "eval_tiny", "logits_tiny", "gradimp_tiny",
                     "pretrain_tiny_m", "sft_tiny_p50_q",
                     "logits_tiny_pallas", "logits_tiny_jnp"]:
        assert required in names, required


def test_std_suite_covers_experiment_configs():
    names = [a.name for a in aot.build_suite("std")]
    # fig3/4 + tab1-3 families
    for n in ["sft_l7b", "sft_l13b", "sft_l13b_m", "sft_l13b_p65",
              "pretrain_l13b_p65", "pretrain_l13b_m",
              # fig7/8 sweep
              "sft_l70b_p65_q", "sft_l70b_p75_q", "sft_l70b_p85_q",
              "sft_l70b_p95_q",
              # llama-3.1 family
              "sft_l8b", "sft_l70b3", "sft_l70b3_p85_q",
              # e2e
              "pretrain_e2e100m", "eval_e2e100m"]:
        assert n in names, n


def test_sft_artifact_input_order_is_canonical():
    """The Rust runtime Session depends on this exact flat-input convention:
    step, lr, tokens, loss_mask, params, [quant], [masks], lora, m, v."""
    art = aot.sft_artifact(PRESETS["tiny"], quantized=True, b=2, s=16)
    names = [n for n, _ in art.in_specs]
    assert names[:4] == ["step", "lr", "tokens", "loss_mask"]
    pn = art.extra["param_names"]
    qn = art.extra["quant_names"]
    ln = art.extra["lora_names"]
    i = 4
    assert names[i:i + len(pn)] == pn
    i += len(pn)
    assert names[i:i + len(qn)] == qn
    i += len(qn)
    assert names[i:i + len(ln)] == ln
    i += len(ln)
    assert names[i:i + len(ln)] == ["adam_m." + n for n in ln]
    i += len(ln)
    assert names[i:i + len(ln)] == ["adam_v." + n for n in ln]
    # outputs: loss then new state in lora order
    assert art.out_names[0] == "loss"
    assert art.out_names[1:1 + len(ln)] == ["new." + n for n in ln]


def test_quantized_artifact_drops_f32_projections():
    art = aot.sft_artifact(PRESETS["tiny"], quantized=True, b=2, s=16)
    names = [n for n, _ in art.in_specs]
    assert "l0.wq" not in names
    assert "l0.wq.codes" in names and "l0.wq.absmax" in names
    # embeddings / norms / lm_head stay f32
    assert "embed" in names and "lm_head" in names and "l0.attn_norm" in names


def test_pruned_cfg_plan_shapes_flow_into_artifact():
    cfg = pruned_config(PRESETS["tiny"], 0.5)
    art = aot.sft_artifact(cfg, b=2, s=16)
    # a pruned middle layer's wq input is narrower than the full one
    full = PRESETS["tiny"]
    mid = 1  # tiny protects first 2? n_layers=2 -> protect 2 first, 1 last
    # find any projection whose shape shrank
    shrunk = False
    for (n, spec) in art.in_specs:
        if n.endswith(".w_gate"):
            li = int(n.split(".")[0][1:])
            h, kv, ff = cfg.layer_shapes(li)
            assert list(spec.shape) == [cfg.d_model, ff]
            if ff < full.d_ff:
                shrunk = True
    assert shrunk or cfg.param_count() == full.param_count()


def test_nf4_block_divides_every_quantized_dim():
    """Only configs that actually receive _q artifacts must satisfy the
    block-alignment constraint (l8b's head_dim 28 never quantises)."""
    quantized = [("tiny", 0.5), ("l70b", 0.65), ("l70b", 0.75),
                 ("l70b", 0.85), ("l70b", 0.95), ("l70b3", 0.85)]
    for name, ratio in quantized:
        p = pruned_config(PRESETS[name], ratio)
        for i in range(p.n_layers):
            for k, (m, n) in M.layer_proj_shapes(p, i).items():
                assert n % aot.NF4_BLOCK == 0, (name, ratio, i, k, n)


def test_eval_artifact_reports_per_sequence():
    art = aot.eval_artifact(PRESETS["tiny"], b=3, s=16)
    outs = {o: None for o in art.out_names}
    assert set(outs) == {"nll_sum", "tok_count"}


def test_suites_register_decode_artifact_trio():
    """`python -m compile.aot --list`-style smoke check: the decode trio
    (prefill + step + speculative verify) is present wherever a logits
    artifact serves decoding."""
    for suite in ("smoke", "std"):
        names = [a.name for a in aot.build_suite(suite)]
        for n in names:
            if n.startswith("decode_prefill_") and \
                    not n.startswith("decode_prefill_chunk_"):
                assert n.replace("decode_prefill_", "decode_step_") in names
                assert n.replace("decode_prefill_", "decode_verify_") in names
    smoke = [a.name for a in aot.build_suite("smoke")]
    for n in ["decode_prefill_tiny", "decode_step_tiny", "decode_verify_tiny",
              # the pruned proxy's own trio: the drafter side of
              # "draft small, verify large"
              "logits_tiny_p50", "decode_prefill_tiny_p50",
              "decode_step_tiny_p50", "decode_verify_tiny_p50"]:
        assert n in smoke, n


def test_decode_step_artifact_declares_cache_donation():
    """Input order tokens, pos, params, lora, caches; every cache output
    donates onto its own input slot and is zero-init-able."""
    cfg = PRESETS["tiny"]
    art = aot.decode_step_artifact(cfg, b=2, s=16)
    names = [n for n, _ in art.in_specs]
    assert names[:2] == ["tokens", "pos"]
    pn, ln, cn = (art.extra["param_names"], art.extra["lora_names"],
                  art.extra["cache_names"])
    i = 2
    assert names[i:i + len(pn)] == pn
    i += len(pn)
    assert names[i:i + len(ln)] == ln
    i += len(ln)
    assert names[i:] == cn
    assert art.extra["state_bindings"] == {"new." + n: n for n in cn}
    assert art.extra["state_zero_init"] == cn
    assert art.out_names == ["logits"] + ["new." + n for n in cn]
    # cache shapes: (B, S, kv_i, hd), per-layer
    specs = dict(art.in_specs)
    for li in range(cfg.n_layers):
        _, kv, _ = cfg.layer_shapes(li)
        assert list(specs[f"cache_k.l{li}"].shape) == \
            [2, 16, kv, cfg.head_dim]
    # abstract eval: logits (B, V), cache outputs mirror cache inputs
    outs = jax.eval_shape(art.fn, *[s for _, s in art.in_specs])
    assert list(outs[0].shape) == [2, cfg.vocab_size]
    for o, n in zip(outs[1:], cn):
        assert list(o.shape) == list(specs[n].shape), n


def test_adapter_quartet_in_suites():
    """Multi-adapter serving quartet ships with the suites; every member
    shares one grid and one adapter group size."""
    smoke = {a.name: a for a in aot.build_suite("smoke")}
    members = ("logits_tiny_a3", "decode_prefill_tiny_a3",
               "decode_step_tiny_a3", "decode_verify_tiny_a3")
    for n in members:
        assert n in smoke, n
    grids = {(smoke[n].extra["batch"], smoke[n].extra["seq"])
             for n in members}
    assert len(grids) == 1
    std = [a.name for a in aot.build_suite("std")]
    assert "logits_l13b_a4" in std and "decode_step_l13b_a4" in std
    assert "decode_verify_l13b_a4" in std


def test_decode_verify_artifact_declares_window_and_donation():
    """Input order tokens, pos, params, lora, caches; the tokens input is a
    (B, draft_k+1) window; cache donation matches the decode step's."""
    cfg = PRESETS["tiny"]
    art = aot.decode_verify_artifact(cfg, b=2, s=16, k=3)
    names = [n for n, _ in art.in_specs]
    assert names[:2] == ["tokens", "pos"]
    assert art.extra["kind"] == "decode_verify"
    assert art.extra["draft_k"] == 3
    specs = dict(art.in_specs)
    assert list(specs["tokens"].shape) == [2, 4]
    cn = art.extra["cache_names"]
    assert art.extra["state_bindings"] == {"new." + n: n for n in cn}
    assert art.extra["state_zero_init"] == cn
    step = aot.decode_step_artifact(cfg, b=2, s=16)
    for n in cn:  # bitwise-identical cache tensors across the trio
        assert list(specs[n].shape) == list(dict(step.in_specs)[n].shape), n
    # abstract eval: logits at every window position
    outs = jax.eval_shape(art.fn, *[s for _, s in art.in_specs])
    assert list(outs[0].shape) == [2, 4, cfg.vocab_size]
    for o, n in zip(outs[1:], cn):
        assert list(o.shape) == list(specs[n].shape), n


def test_chunk_ladder_formula():
    """The ladder formula is the Rust discovery contract
    (kvcache::chunk_ladder) — keep both sides in lockstep."""
    assert aot.chunk_ladder(8) == [8]
    assert aot.chunk_ladder(16) == [16]
    assert aot.chunk_ladder(32) == [16, 32]
    assert aot.chunk_ladder(64) == [16, 64]
    assert aot.chunk_ladder(128) == [16, 64, 128]


def test_suites_register_chunk_ladder():
    """Every decode family ships its chunked-prefill bucket ladder, the
    adapter quartet included."""
    smoke = [a.name for a in aot.build_suite("smoke")]
    for n in ["decode_prefill_chunk_tiny_c16", "decode_prefill_chunk_tiny_c32",
              "decode_prefill_chunk_tiny_p50_c16",
              "decode_prefill_chunk_tiny_p50_c32",
              "decode_prefill_chunk_tiny_a3_c16",
              "decode_prefill_chunk_tiny_a3_c32"]:
        assert n in smoke, n
    std = [a.name for a in aot.build_suite("std")]
    for n in ["decode_prefill_chunk_l13b_c16", "decode_prefill_chunk_l13b_c64",
              "decode_prefill_chunk_l13b_a4_c16"]:
        assert n in std, n


def test_decode_prefill_chunk_artifact_declares_window_and_donation():
    """Input order tokens, start_pos, last_pos, row_onehot, params, lora,
    caches; the tokens input is a (1, chunk) window; cache donation matches
    the decode step's."""
    cfg = PRESETS["tiny"]
    art = aot.decode_prefill_chunk_artifact(cfg, 8, b=2, s=16)
    names = [n for n, _ in art.in_specs]
    assert names[:4] == ["tokens", "start_pos", "last_pos", "row_onehot"]
    assert art.extra["kind"] == "decode_prefill_chunk"
    assert art.extra["chunk"] == 8
    specs = dict(art.in_specs)
    assert list(specs["tokens"].shape) == [1, 8]
    assert list(specs["start_pos"].shape) == []
    assert list(specs["last_pos"].shape) == []
    assert list(specs["row_onehot"].shape) == [2]
    cn = art.extra["cache_names"]
    assert art.extra["state_bindings"] == {"new." + n: n for n in cn}
    assert art.extra["state_zero_init"] == cn
    step = aot.decode_step_artifact(cfg, b=2, s=16)
    for n in cn:  # bitwise-identical cache tensors across the family
        assert list(specs[n].shape) == list(dict(step.in_specs)[n].shape), n
    outs = jax.eval_shape(art.fn, *[s for _, s in art.in_specs])
    assert list(outs[0].shape) == [1, cfg.vocab_size]
    for o, n in zip(outs[1:], cn):
        assert list(o.shape) == list(specs[n].shape), n
    # the stacked variant keeps the adapter group + scalar gather
    a = aot.decode_prefill_chunk_adapters_artifact(cfg, 3, 8, b=4, s=16)
    anames = [n for n, _ in a.in_specs]
    assert anames[:5] == ["tokens", "start_pos", "last_pos", "row_onehot",
                          "adapter_ix"]
    g = a.extra["slot_groups"]["adapter"]
    assert g["input"] == "adapter_ix" and g["size"] == 3
    aouts = jax.eval_shape(a.fn, *[s for _, s in a.in_specs])
    assert list(aouts[0].shape) == [1, cfg.vocab_size]


def test_meta_check_flags_chunk_window_violations():
    """The ci.sh meta validator accepts a real chunk meta and rejects the
    violations runtime::meta / KvDecoder would reject."""
    from compile.meta_check import check_meta
    import copy
    art = aot.decode_prefill_chunk_artifact(PRESETS["tiny"], 8, b=2, s=16)
    meta = art.meta_dict()
    assert check_meta(meta) == []

    broken = copy.deepcopy(meta)
    broken["extra"]["chunk"] = 0
    assert any("bad chunk" in e for e in check_meta(broken))

    broken = copy.deepcopy(meta)
    # bool passes isinstance(int) in python but the Rust mirror's
    # as_usize() rejects it — the validator must too
    broken["extra"]["chunk"] = True
    assert any("bad chunk" in e for e in check_meta(broken))

    broken = copy.deepcopy(meta)
    broken["extra"]["chunk"] = 12  # tokens window no longer matches
    assert any("(1, 12)" in e for e in check_meta(broken))

    broken = copy.deepcopy(meta)
    broken["extra"]["chunk"] = 32  # window longer than the cache grid
    broken["inputs"] = [
        {**e, "shape": [1, 32]} if e["name"] == "tokens" else e
        for e in broken["inputs"]]
    assert any("exceeds" in e for e in check_meta(broken))

    broken = copy.deepcopy(meta)
    broken["inputs"] = [e for e in broken["inputs"]
                        if e["name"] != "start_pos"]
    assert any("start_pos" in e for e in check_meta(broken))

    broken = copy.deepcopy(meta)
    for e in broken["inputs"]:
        if e["name"] == "last_pos":
            e["dtype"] = "float32"
    assert any("last_pos" in e for e in check_meta(broken))


def test_adapter_artifacts_declare_slot_group():
    """Input order and the adapter slot-group meta contract: adapter_ix
    gathers along the stacked leading axis of every lora member; members
    are zero-init-able so an empty session serves the base model."""
    cfg = PRESETS["tiny"]
    n = 3
    for art, head in [
        (aot.logits_adapters_artifact(cfg, n, b=4, s=16),
         ["tokens", "adapter_ix"]),
        (aot.decode_prefill_adapters_artifact(cfg, n, b=4, s=16),
         ["tokens", "last_pos", "row_onehot", "adapter_ix"]),
        (aot.decode_step_adapters_artifact(cfg, n, b=4, s=16),
         ["tokens", "pos", "adapter_ix"]),
    ]:
        names = [nm for nm, _ in art.in_specs]
        assert names[:len(head)] == head, art.name
        g = art.extra["slot_groups"]["adapter"]
        assert g["input"] == "adapter_ix"
        assert g["size"] == n
        ln = art.extra["lora_names"]
        assert g["members"] == ln
        specs = dict(art.in_specs)
        base = M.lora_shapes(cfg)
        for m in ln:
            assert list(specs[m].shape) == [n] + list(base[m]), (art.name, m)
            assert m in art.extra["state_zero_init"], (art.name, m)
        # decode members keep cache donation intact alongside the group
        if art.name.startswith("decode_"):
            cn = art.extra["cache_names"]
            assert art.extra["state_bindings"] == {"new." + c: c for c in cn}
            for c in cn:
                assert c in art.extra["state_zero_init"]
        # abstract eval round-trips
        outs = jax.eval_shape(art.fn, *[s for _, s in art.in_specs])
        assert list(outs[0].shape)[-1] == cfg.vocab_size


def test_meta_check_mirror_accepts_suite_and_rejects_violations():
    """The ci.sh meta validator accepts a real adapter meta and flags the
    violations the Rust runtime would reject."""
    from compile.meta_check import check_meta
    art = aot.decode_step_adapters_artifact(PRESETS["tiny"], 3, b=2, s=16)
    meta = art.meta_dict()
    assert check_meta(meta) == []

    import copy
    broken = copy.deepcopy(meta)
    broken["extra"]["state_bindings"]["new.cache_k.l0"] = "nope"
    assert any("nope" in e for e in check_meta(broken))

    broken = copy.deepcopy(meta)
    first = broken["extra"]["slot_groups"]["adapter"]["members"][0]
    for e in broken["inputs"]:
        if e["name"] == first:
            e["shape"][0] += 1  # member no longer stacks `size` slots
    assert any("stack" in e for e in check_meta(broken))

    broken = copy.deepcopy(meta)
    broken["extra"]["slot_groups"]["adapter"]["input"] = "missing_ix"
    assert any("missing_ix" in e for e in check_meta(broken))

    broken = copy.deepcopy(meta)
    del broken["config"]["d_model"]
    assert any("d_model" in e for e in check_meta(broken))


def test_decode_prefill_artifact_is_single_row():
    cfg = PRESETS["tiny"]
    art = aot.decode_prefill_artifact(cfg, b=2, s=16)
    specs = dict(art.in_specs)
    assert list(specs["tokens"].shape) == [1, 16]
    assert list(specs["last_pos"].shape) == []
    assert list(specs["row_onehot"].shape) == [2]
    assert art.extra["state_bindings"] == \
        {"new." + n: n for n in art.extra["cache_names"]}
    outs = jax.eval_shape(art.fn, *[s for _, s in art.in_specs])
    assert list(outs[0].shape) == [1, cfg.vocab_size]


# ---------------------------------------------------------------------------
# Paged decode artifacts (DESIGN.md §2f)
# ---------------------------------------------------------------------------

def test_suites_register_paged_decode_family():
    """The paged family mirrors the dense decode family one-for-one —
    prefill + step + verify + the chunk ladder — wherever it ships."""
    smoke = [a.name for a in aot.build_suite("smoke")]
    for n in ["decode_prefill_paged_tiny", "decode_step_paged_tiny",
              "decode_verify_paged_tiny",
              "decode_prefill_chunk_paged_tiny_c16",
              "decode_prefill_chunk_paged_tiny_c32"]:
        assert n in smoke, n
    std = [a.name for a in aot.build_suite("std")]
    for n in ["decode_prefill_paged_l13b", "decode_step_paged_l13b",
              "decode_verify_paged_l13b",
              "decode_prefill_chunk_paged_l13b_c16",
              "decode_prefill_chunk_paged_l13b_c64"]:
        assert n in std, n


def test_paged_pool_blocks_formula():
    """Like `chunk_ladder`, the default pool size is a discovery contract
    with the Rust paged decoder: the pool holds exactly the dense grid's
    bytes, so the capacity win is pure packing."""
    assert aot.paged_pool_blocks(2, 32, 8) == 8
    assert aot.paged_pool_blocks(4, 64, 8) == 32
    assert aot.paged_pool_blocks(4, 64, 16) == 16


def test_decode_step_paged_artifact_declares_pool_and_donation():
    """Input order tokens, pos, block_table, params, lora, pooled caches;
    `extra.paged` carries the block geometry; donation matches dense."""
    cfg = PRESETS["tiny"]
    art = aot.decode_step_paged_artifact(cfg, b=2, s=16, block=4)
    names = [n for n, _ in art.in_specs]
    assert names[:3] == ["tokens", "pos", "block_table"]
    pn, ln, cn = (art.extra["param_names"], art.extra["lora_names"],
                  art.extra["cache_names"])
    i = 3
    assert names[i:i + len(pn)] == pn
    i += len(pn)
    assert names[i:i + len(ln)] == ln
    i += len(ln)
    assert names[i:] == cn
    assert art.extra["paged"] == {"block_size": 4, "n_blocks": 8}
    assert art.extra["state_bindings"] == {"new." + n: n for n in cn}
    assert art.extra["state_zero_init"] == cn
    specs = dict(art.in_specs)
    assert list(specs["block_table"].shape) == [2, 4]
    assert specs["block_table"].dtype == jnp.int32
    for li in range(cfg.n_layers):
        _, kv, _ = cfg.layer_shapes(li)
        assert list(specs[f"cache_k.l{li}"].shape) == [8, 4, kv, cfg.head_dim]
    outs = jax.eval_shape(art.fn, *[s for _, s in art.in_specs])
    assert list(outs[0].shape) == [2, cfg.vocab_size]
    for o, n in zip(outs[1:], cn):
        assert list(o.shape) == list(specs[n].shape), n


def test_decode_prefill_chunk_paged_artifact_has_table_not_onehot():
    """The paged chunk window drops row_onehot — the (S/block,) table is
    the row selection — and keeps the window scalars."""
    cfg = PRESETS["tiny"]
    art = aot.decode_prefill_chunk_paged_artifact(cfg, 8, b=2, s=16, block=4)
    names = [n for n, _ in art.in_specs]
    assert names[:4] == ["tokens", "start_pos", "last_pos", "block_table"]
    assert "row_onehot" not in names
    assert art.extra["kind"] == "decode_prefill_chunk"
    assert art.extra["chunk"] == 8
    assert art.extra["paged"] == {"block_size": 4, "n_blocks": 8}
    specs = dict(art.in_specs)
    assert list(specs["block_table"].shape) == [4]
    outs = jax.eval_shape(art.fn, *[s for _, s in art.in_specs])
    assert list(outs[0].shape) == [1, cfg.vocab_size]


def test_meta_check_flags_paged_violations():
    """The ci.sh meta validator accepts real paged metas and rejects the
    contract breaks runtime::meta's paged mirror would reject."""
    from compile.meta_check import check_meta
    import copy
    for art in aot.decode_paged_artifacts(PRESETS["tiny"], b=2, s=32):
        assert check_meta(art.meta_dict()) == [], art.name

    meta = aot.decode_step_paged_artifact(PRESETS["tiny"], b=2, s=16,
                                          block=4).meta_dict()
    broken = copy.deepcopy(meta)
    broken["extra"]["paged"]["block_size"] = 0
    assert any("bad block_size" in e for e in check_meta(broken))

    broken = copy.deepcopy(meta)
    broken["extra"]["paged"]["n_blocks"] = True  # bool is not a JSON int
    assert any("bad n_blocks" in e for e in check_meta(broken))

    broken = copy.deepcopy(meta)
    broken["extra"]["paged"]["block_size"] = 5  # 16 % 5 != 0
    assert any("whole number" in e for e in check_meta(broken))

    broken = copy.deepcopy(meta)
    broken["inputs"] = [e for e in broken["inputs"]
                        if e["name"] != "block_table"]
    assert any("no block_table" in e for e in check_meta(broken))

    broken = copy.deepcopy(meta)
    for e in broken["inputs"]:
        if e["name"] == "block_table":
            e["shape"] = [4]  # step needs the batched (B, S/block) table
    assert any("block_table shape" in e for e in check_meta(broken))

    broken = copy.deepcopy(meta)
    for e in broken["inputs"]:
        if e["name"] == "cache_k.l0":
            e["shape"] = [2, 16, 2, 32]  # dense grid fed to a paged meta
    assert any("not pooled" in e for e in check_meta(broken))

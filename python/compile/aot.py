"""AOT lowering: JAX (L2) -> HLO text artifacts consumed by the Rust runtime.

HLO *text* (not `lowered.compile()` / proto `.serialize()`) is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction
ids that xla_extension 0.5.1 (behind the `xla` 0.1.6 crate) rejects; the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Every artifact `<name>.hlo.txt` is written together with `<name>.meta.json`
describing the exact input/output tensor order, shapes and dtypes plus the
model config — the Rust runtime is driven entirely by that metadata.

Usage:
    python -m compile.aot --out-dir ../artifacts [--suite std|smoke] \
        [--only regex] [--list] [--pallas]
"""

import argparse
import json
import os
import re
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import PRESETS, ModelConfig, pruned_config
from . import model as M

# Per-artifact static shapes (proxy scale for the single-core testbed; the
# paper's 512-token/batch-128 setup is noted in DESIGN.md §Perf).
TRAIN_B, TRAIN_S = 4, 64
EVAL_B, EVAL_S = 8, 64
LOGITS_B, LOGITS_S = 4, 64
# Block 16 divides every projection dim across the preset family (the paper
# uses QLoRA's 64; storage accounting in rust/src/quant covers both).
NF4_BLOCK = 16
# Draft window for speculative decoding: the decode_verify artifacts score
# K drafted tokens (+ the frontier) per call (DESIGN.md §2d).
DRAFT_K = 4


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _io_entry(name, s):
    return {"name": name, "shape": list(s.shape), "dtype": str(s.dtype)}


class Artifact:
    def __init__(self, name, fn, in_specs, out_names, cfg: ModelConfig,
                 extra=None):
        self.name = name
        self.fn = fn
        self.in_specs = in_specs          # list[(name, ShapeDtypeStruct)]
        self.out_names = out_names
        self.cfg = cfg
        self.extra = extra or {}

    def meta_dict(self):
        """The `.meta.json` content, computed by abstract evaluation only —
        no HLO lowering. Shared by `emit` and the meta_check validator."""
        specs = [s for _, s in self.in_specs]
        outs = jax.eval_shape(self.fn, *specs)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        assert len(outs) == len(self.out_names), \
            (self.name, len(outs), len(self.out_names))
        return {
            "name": self.name,
            "config": self.cfg.to_dict(),
            "inputs": [_io_entry(n, s) for n, s in self.in_specs],
            "outputs": [_io_entry(n, s) for n, s in zip(self.out_names, outs)],
            "extra": self.extra,
        }

    def emit(self, out_dir):
        t0 = time.time()
        meta = self.meta_dict()
        specs = [s for _, s in self.in_specs]
        lowered = jax.jit(self.fn).lower(*specs)
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, f"{self.name}.hlo.txt"), "w") as f:
            f.write(text)
        with open(os.path.join(out_dir, f"{self.name}.meta.json"), "w") as f:
            json.dump(meta, f, indent=1)
        print(f"  {self.name}: {len(text)//1024} KiB hlo, "
              f"{len(self.in_specs)} in / {len(self.out_names)} out, "
              f"{time.time()-t0:.1f}s", flush=True)


# ---------------------------------------------------------------------------
# Spec helpers for each artifact kind
# ---------------------------------------------------------------------------

def _param_specs(cfg, names=None):
    shapes = M.param_shapes(cfg)
    names = names if names is not None else list(shapes.keys())
    return [(n, _spec(shapes[n])) for n in names]


def _lora_specs(cfg, prefix=""):
    return [(prefix + n, _spec(s)) for n, s in M.lora_shapes(cfg).items()]


def _mask_specs(cfg):
    shapes = M.layer_proj_shapes(cfg, 0)
    out = []
    for i in range(cfg.n_layers):
        ls = M.layer_proj_shapes(cfg, i)
        for k in M.LAYER_PROJ:
            out.append((f"l{i}.{k}.mask", _spec(ls[k])))
    return out


def _quant_specs(cfg):
    out = []
    for i in range(cfg.n_layers):
        ls = M.layer_proj_shapes(cfg, i)
        for k in M.QUANT_PROJ:
            m, n = ls[k]
            out.append((f"l{i}.{k}.codes", _spec((m, n), jnp.int32)))
            out.append((f"l{i}.{k}.absmax", _spec((m, n // NF4_BLOCK))))
    return out


def _state_threading(trained_names):
    """Declared output->input donation map for an optimiser step artifact.

    The Rust `Session` threads each step's state outputs back onto their
    input slots using exactly this declaration (no name-prefix guessing on
    the Rust side); `state_zero_init` marks the inputs the session may
    zero-fill when the caller supplies no optimiser state.
    """
    bindings = {}
    for n in trained_names:
        bindings["new." + n] = n
        bindings["new_m." + n] = "adam_m." + n
        bindings["new_v." + n] = "adam_v." + n
    zero_init = (["adam_m." + n for n in trained_names]
                 + ["adam_v." + n for n in trained_names])
    return {"state_bindings": bindings, "state_zero_init": zero_init}


def pretrain_artifact(cfg, masked=False, b=TRAIN_B, s=TRAIN_S, tag=""):
    fn, pnames, mnames = M.make_pretrain_step(cfg, masked=masked)
    ins = [("step", _spec((), jnp.float32)), ("lr", _spec((), jnp.float32)),
           ("tokens", _spec((b, s + 1), jnp.int32)),
           ("loss_mask", _spec((b, s)))]
    ins += _param_specs(cfg, pnames)
    ins += [("adam_m." + n, sp) for n, sp in _param_specs(cfg, pnames)]
    ins += [("adam_v." + n, sp) for n, sp in _param_specs(cfg, pnames)]
    if masked:
        ins += _mask_specs(cfg)
    outs = (["loss"] + ["new." + n for n in pnames]
            + ["new_m." + n for n in pnames] + ["new_v." + n for n in pnames])
    name = f"pretrain_{cfg.name}{tag}" + ("_m" if masked else "")
    return Artifact(name, fn, ins, outs, cfg,
                    {"kind": "pretrain", "batch": b, "seq": s,
                     "masked": masked, "param_names": pnames,
                     "mask_names": mnames, **_state_threading(pnames)})


def sft_artifact(cfg, masked=False, quantized=False, b=TRAIN_B, s=TRAIN_S):
    fn, pnames, qnames, mnames, lnames = M.make_sft_step(
        cfg, masked=masked, quantized=quantized, nf4_block=NF4_BLOCK)
    ins = [("step", _spec((), jnp.float32)), ("lr", _spec((), jnp.float32)),
           ("tokens", _spec((b, s + 1), jnp.int32)),
           ("loss_mask", _spec((b, s)))]
    ins += _param_specs(cfg, pnames)
    if quantized:
        ins += _quant_specs(cfg)
    if masked:
        ins += _mask_specs(cfg)
    ins += _lora_specs(cfg)
    ins += [("adam_m." + n, sp) for n, sp in _lora_specs(cfg)]
    ins += [("adam_v." + n, sp) for n, sp in _lora_specs(cfg)]
    outs = (["loss"] + ["new." + n for n in lnames]
            + ["new_m." + n for n in lnames] + ["new_v." + n for n in lnames])
    tag = ("_m" if masked else "") + ("_q" if quantized else "")
    return Artifact(f"sft_{cfg.name}{tag}", fn, ins, outs, cfg,
                    {"kind": "sft", "batch": b, "seq": s, "masked": masked,
                     "quantized": quantized, "nf4_block": NF4_BLOCK,
                     "param_names": pnames, "quant_names": qnames,
                     "mask_names": mnames, "lora_names": lnames,
                     **_state_threading(lnames)})


def eval_artifact(cfg, b=EVAL_B, s=EVAL_S):
    fn, pnames, lnames = M.make_eval_loss(cfg)
    ins = [("tokens", _spec((b, s + 1), jnp.int32)),
           ("loss_mask", _spec((b, s)))]
    ins += _param_specs(cfg, pnames)
    ins += _lora_specs(cfg)
    return Artifact(f"eval_{cfg.name}", fn, ins, ["nll_sum", "tok_count"],
                    cfg, {"kind": "eval", "batch": b, "seq": s,
                          "param_names": pnames, "lora_names": lnames})


def logits_artifact(cfg, b=LOGITS_B, s=LOGITS_S):
    fn, pnames, lnames = M.make_logits(cfg)
    ins = [("tokens", _spec((b, s), jnp.int32))]
    ins += _param_specs(cfg, pnames)
    ins += _lora_specs(cfg)
    return Artifact(f"logits_{cfg.name}", fn, ins, ["logits"], cfg,
                    {"kind": "logits", "batch": b, "seq": s,
                     "param_names": pnames, "lora_names": lnames})


def _cache_specs(cfg, b, s):
    return [(n, _spec(shp)) for n, shp in M.kv_cache_shapes(cfg, b, s).items()]


def _cache_threading(cnames):
    """Cache tensors are donated state: each `new.cache_*` output rebinds
    onto its input slot (Session state threading), and a fresh session may
    zero-fill the caches — the decode analogue of `_state_threading`."""
    return {"state_bindings": {"new." + n: n for n in cnames},
            "state_zero_init": list(cnames)}


def decode_prefill_artifact(cfg, b=LOGITS_B, s=LOGITS_S):
    """Admission-time cache fill for one row (tokens are (1, S); the row is
    selected by `row_onehot`, all other rows' caches pass through)."""
    fn, pnames, lnames, cnames = M.make_decode_prefill(cfg)
    ins = [("tokens", _spec((1, s), jnp.int32)),
           ("last_pos", _spec((), jnp.int32)),
           ("row_onehot", _spec((b,)))]
    ins += _param_specs(cfg, pnames)
    ins += _lora_specs(cfg)
    ins += _cache_specs(cfg, b, s)
    outs = ["logits"] + ["new." + n for n in cnames]
    return Artifact(f"decode_prefill_{cfg.name}", fn, ins, outs, cfg,
                    {"kind": "decode_prefill", "batch": b, "seq": s,
                     "param_names": pnames, "lora_names": lnames,
                     "cache_names": cnames, **_cache_threading(cnames)})


def decode_step_artifact(cfg, b=LOGITS_B, s=LOGITS_S):
    """(B, 1) incremental decode step: per-row frontier token + position in,
    next-token logits out; K/V caches live on device as donated state."""
    fn, pnames, lnames, cnames = M.make_decode_step(cfg)
    ins = [("tokens", _spec((b, 1), jnp.int32)),
           ("pos", _spec((b,), jnp.int32))]
    ins += _param_specs(cfg, pnames)
    ins += _lora_specs(cfg)
    ins += _cache_specs(cfg, b, s)
    outs = ["logits"] + ["new." + n for n in cnames]
    return Artifact(f"decode_step_{cfg.name}", fn, ins, outs, cfg,
                    {"kind": "decode_step", "batch": b, "seq": s,
                     "param_names": pnames, "lora_names": lnames,
                     "cache_names": cnames, **_cache_threading(cnames)})


def chunk_ladder(s):
    """Chunked-prefill bucket ladder for an S-long decode grid: a short
    bucket for quick prompts, a medium one, and the full grid. The formula
    — not the manifest — is the discovery contract: the Rust
    `kvcache::chunk_ladder` mirror probes exactly these bucket names."""
    return sorted({min(16, s), min(64, s), s})


def decode_prefill_chunk_artifact(cfg, chunk, b=LOGITS_B, s=LOGITS_S):
    """Chunked admission (DESIGN.md §2e): one (1, C) prompt window
    forwarded at `start_pos`, its K/V scattered into the
    `row_onehot`-selected cache row at start_pos..start_pos+C; logits come
    back at window index `last_pos` (only the final chunk's are
    meaningful). Caches stay donated state, bitwise-identical to the
    decode trio's."""
    fn, pnames, lnames, cnames = M.make_decode_prefill_chunk(cfg)
    ins = [("tokens", _spec((1, chunk), jnp.int32)),
           ("start_pos", _spec((), jnp.int32)),
           ("last_pos", _spec((), jnp.int32)),
           ("row_onehot", _spec((b,)))]
    ins += _param_specs(cfg, pnames)
    ins += _lora_specs(cfg)
    ins += _cache_specs(cfg, b, s)
    outs = ["logits"] + ["new." + n for n in cnames]
    return Artifact(f"decode_prefill_chunk_{cfg.name}_c{chunk}", fn, ins,
                    outs, cfg,
                    {"kind": "decode_prefill_chunk", "batch": b, "seq": s,
                     "chunk": chunk, "param_names": pnames,
                     "lora_names": lnames, "cache_names": cnames,
                     **_cache_threading(cnames)})


def decode_verify_artifact(cfg, b=LOGITS_B, s=LOGITS_S, k=DRAFT_K):
    """(B, K+1) speculative verification window: each row feeds its frontier
    token + K draft candidates starting at `pos`; logits come back at every
    window position so one call scores a whole draft run. Caches stay
    donated state, bitwise-identical to the prefill/step pair's."""
    fn, pnames, lnames, cnames = M.make_decode_verify(cfg)
    ins = [("tokens", _spec((b, k + 1), jnp.int32)),
           ("pos", _spec((b,), jnp.int32))]
    ins += _param_specs(cfg, pnames)
    ins += _lora_specs(cfg)
    ins += _cache_specs(cfg, b, s)
    outs = ["logits"] + ["new." + n for n in cnames]
    return Artifact(f"decode_verify_{cfg.name}", fn, ins, outs, cfg,
                    {"kind": "decode_verify", "batch": b, "seq": s,
                     "draft_k": k, "param_names": pnames,
                     "lora_names": lnames, "cache_names": cnames,
                     **_cache_threading(cnames)})


def decode_artifacts(cfg, b=LOGITS_B, s=LOGITS_S, k=DRAFT_K):
    """The decode family always ships together: prefill + step (the
    Generator pair), the speculative verify window, and the chunked-prefill
    bucket ladder (one (1, C) window artifact per `chunk_ladder` entry)."""
    return ([decode_prefill_artifact(cfg, b, s), decode_step_artifact(cfg, b, s),
             decode_verify_artifact(cfg, b, s, k)]
            + [decode_prefill_chunk_artifact(cfg, c, b, s)
               for c in chunk_ladder(s)])


# ---------------------------------------------------------------------------
# Paged decode artifacts (DESIGN.md §2f: block pool + per-row block tables)
# ---------------------------------------------------------------------------

PAGED_BLOCK = 8


def paged_pool_blocks(b, s, block=PAGED_BLOCK):
    """Default artifact pool size: exactly the bytes of the dense (B, S)
    grid — B rows' worth of full-length tables — so paged-vs-dense A/Bs
    hold pool bytes fixed and the capacity win comes purely from packing.
    Like `chunk_ladder`, the formula is the discovery contract: the Rust
    paged decoder derives n_blocks the same way when sizing its pool."""
    return b * (s // block)


def _paged_cache_specs(cfg, n_blocks, block):
    return [(n, _spec(shp))
            for n, shp in M.paged_cache_shapes(cfg, n_blocks, block).items()]


def _paged_extra(block, n_blocks):
    """The `extra.paged` contract (meta_check + runtime::meta mirror)."""
    return {"paged": {"block_size": block, "n_blocks": n_blocks}}


def decode_prefill_paged_artifact(cfg, b=LOGITS_B, s=LOGITS_S,
                                  block=PAGED_BLOCK, n_blocks=None):
    """Paged `decode_prefill`: the admitted row's `(S/block,)` block table
    replaces `row_onehot` — it names the row's physical pool blocks, so
    selection and isolation are the same fact."""
    n_blocks = paged_pool_blocks(b, s, block) if n_blocks is None else n_blocks
    fn, pnames, lnames, cnames = M.make_decode_prefill_paged(cfg)
    ins = [("tokens", _spec((1, s), jnp.int32)),
           ("last_pos", _spec((), jnp.int32)),
           ("block_table", _spec((s // block,), jnp.int32))]
    ins += _param_specs(cfg, pnames)
    ins += _lora_specs(cfg)
    ins += _paged_cache_specs(cfg, n_blocks, block)
    outs = ["logits"] + ["new." + n for n in cnames]
    return Artifact(f"decode_prefill_paged_{cfg.name}", fn, ins, outs, cfg,
                    {"kind": "decode_prefill", "batch": b, "seq": s,
                     "param_names": pnames, "lora_names": lnames,
                     "cache_names": cnames, **_paged_extra(block, n_blocks),
                     **_cache_threading(cnames)})


def decode_step_paged_artifact(cfg, b=LOGITS_B, s=LOGITS_S,
                               block=PAGED_BLOCK, n_blocks=None):
    """Paged `decode_step`: per-row (B, S/block) tables resolve each row's
    logical positions into the shared (n_blocks, block, kv, hd) pool."""
    n_blocks = paged_pool_blocks(b, s, block) if n_blocks is None else n_blocks
    fn, pnames, lnames, cnames = M.make_decode_step_paged(cfg)
    ins = [("tokens", _spec((b, 1), jnp.int32)),
           ("pos", _spec((b,), jnp.int32)),
           ("block_table", _spec((b, s // block), jnp.int32))]
    ins += _param_specs(cfg, pnames)
    ins += _lora_specs(cfg)
    ins += _paged_cache_specs(cfg, n_blocks, block)
    outs = ["logits"] + ["new." + n for n in cnames]
    return Artifact(f"decode_step_paged_{cfg.name}", fn, ins, outs, cfg,
                    {"kind": "decode_step", "batch": b, "seq": s,
                     "param_names": pnames, "lora_names": lnames,
                     "cache_names": cnames, **_paged_extra(block, n_blocks),
                     **_cache_threading(cnames)})


def decode_verify_paged_artifact(cfg, b=LOGITS_B, s=LOGITS_S, k=DRAFT_K,
                                 block=PAGED_BLOCK, n_blocks=None):
    """Paged `decode_verify`: the (B, K+1) speculative window over
    pool-resolved cache slots."""
    n_blocks = paged_pool_blocks(b, s, block) if n_blocks is None else n_blocks
    fn, pnames, lnames, cnames = M.make_decode_verify_paged(cfg)
    ins = [("tokens", _spec((b, k + 1), jnp.int32)),
           ("pos", _spec((b,), jnp.int32)),
           ("block_table", _spec((b, s // block), jnp.int32))]
    ins += _param_specs(cfg, pnames)
    ins += _lora_specs(cfg)
    ins += _paged_cache_specs(cfg, n_blocks, block)
    outs = ["logits"] + ["new." + n for n in cnames]
    return Artifact(f"decode_verify_paged_{cfg.name}", fn, ins, outs, cfg,
                    {"kind": "decode_verify", "batch": b, "seq": s,
                     "draft_k": k, "param_names": pnames,
                     "lora_names": lnames, "cache_names": cnames,
                     **_paged_extra(block, n_blocks),
                     **_cache_threading(cnames)})


def decode_prefill_chunk_paged_artifact(cfg, chunk, b=LOGITS_B, s=LOGITS_S,
                                        block=PAGED_BLOCK, n_blocks=None):
    """Paged chunked admission: one (1, C) window scattered through the
    admitted row's `(S/block,)` table. This is the artifact shared-prefix
    reuse rides on — chunks whose blocks are already resident are simply
    never fed."""
    n_blocks = paged_pool_blocks(b, s, block) if n_blocks is None else n_blocks
    fn, pnames, lnames, cnames = M.make_decode_prefill_chunk_paged(cfg)
    ins = [("tokens", _spec((1, chunk), jnp.int32)),
           ("start_pos", _spec((), jnp.int32)),
           ("last_pos", _spec((), jnp.int32)),
           ("block_table", _spec((s // block,), jnp.int32))]
    ins += _param_specs(cfg, pnames)
    ins += _lora_specs(cfg)
    ins += _paged_cache_specs(cfg, n_blocks, block)
    outs = ["logits"] + ["new." + n for n in cnames]
    return Artifact(f"decode_prefill_chunk_paged_{cfg.name}_c{chunk}", fn,
                    ins, outs, cfg,
                    {"kind": "decode_prefill_chunk", "batch": b, "seq": s,
                     "chunk": chunk, "param_names": pnames,
                     "lora_names": lnames, "cache_names": cnames,
                     **_paged_extra(block, n_blocks),
                     **_cache_threading(cnames)})


def decode_paged_artifacts(cfg, b=LOGITS_B, s=LOGITS_S, k=DRAFT_K,
                           block=PAGED_BLOCK):
    """The paged decode family mirrors `decode_artifacts` one-for-one:
    prefill + step + verify + the chunk ladder, all over one pooled cache
    sized by `paged_pool_blocks`."""
    return ([decode_prefill_paged_artifact(cfg, b, s, block),
             decode_step_paged_artifact(cfg, b, s, block),
             decode_verify_paged_artifact(cfg, b, s, k, block)]
            + [decode_prefill_chunk_paged_artifact(cfg, c, b, s, block)
               for c in chunk_ladder(s)])


# ---------------------------------------------------------------------------
# Multi-adapter serving artifacts (DESIGN.md §2c)
# ---------------------------------------------------------------------------

def _stacked_lora_specs(cfg, n_adapters):
    return [(k, _spec(shp))
            for k, shp in M.stacked_lora_shapes(cfg, n_adapters).items()]


def _adapter_group(n_adapters, lnames):
    """The adapter slot-group declaration: `adapter_ix` gathers along the
    leading axis of every member tensor; the Session's `put_group` uploads
    one member row per registered adapter and re-uploads only dirty slots.
    Members are zero-init-able (a zero adapter is the identity), so a
    session with no registered adapters still serves the base model."""
    return {"slot_groups": {"adapter": {
        "input": "adapter_ix", "size": n_adapters, "members": lnames}}}


def logits_adapters_artifact(cfg, n_adapters, b=LOGITS_B, s=LOGITS_S):
    fn, pnames, lnames = M.make_logits_adapters(cfg, n_adapters)
    ins = [("tokens", _spec((b, s), jnp.int32)),
           ("adapter_ix", _spec((b,), jnp.int32))]
    ins += _param_specs(cfg, pnames)
    ins += _stacked_lora_specs(cfg, n_adapters)
    return Artifact(f"logits_{cfg.name}_a{n_adapters}", fn, ins, ["logits"],
                    cfg, {"kind": "logits", "batch": b, "seq": s,
                          "param_names": pnames, "lora_names": lnames,
                          "state_zero_init": lnames,
                          **_adapter_group(n_adapters, lnames)})


def decode_prefill_adapters_artifact(cfg, n_adapters, b=LOGITS_B, s=LOGITS_S):
    """Adapter-stacked admission: scalar `adapter_ix` names the slot the
    admitted row decodes under; caches stay donated state."""
    fn, pnames, lnames, cnames = M.make_decode_prefill_adapters(cfg, n_adapters)
    ins = [("tokens", _spec((1, s), jnp.int32)),
           ("last_pos", _spec((), jnp.int32)),
           ("row_onehot", _spec((b,))),
           ("adapter_ix", _spec((), jnp.int32))]
    ins += _param_specs(cfg, pnames)
    ins += _stacked_lora_specs(cfg, n_adapters)
    ins += _cache_specs(cfg, b, s)
    outs = ["logits"] + ["new." + n for n in cnames]
    extra = {"kind": "decode_prefill", "batch": b, "seq": s,
             "param_names": pnames, "lora_names": lnames,
             "cache_names": cnames, **_cache_threading(cnames),
             **_adapter_group(n_adapters, lnames)}
    extra["state_zero_init"] = list(cnames) + list(lnames)
    return Artifact(f"decode_prefill_{cfg.name}_a{n_adapters}", fn, ins, outs,
                    cfg, extra)


def decode_step_adapters_artifact(cfg, n_adapters, b=LOGITS_B, s=LOGITS_S):
    """Adapter-stacked decode step: per-row `adapter_ix (B,)` routes each
    row's LoRA contribution through its own slot every step."""
    fn, pnames, lnames, cnames = M.make_decode_step_adapters(cfg, n_adapters)
    ins = [("tokens", _spec((b, 1), jnp.int32)),
           ("pos", _spec((b,), jnp.int32)),
           ("adapter_ix", _spec((b,), jnp.int32))]
    ins += _param_specs(cfg, pnames)
    ins += _stacked_lora_specs(cfg, n_adapters)
    ins += _cache_specs(cfg, b, s)
    outs = ["logits"] + ["new." + n for n in cnames]
    extra = {"kind": "decode_step", "batch": b, "seq": s,
             "param_names": pnames, "lora_names": lnames,
             "cache_names": cnames, **_cache_threading(cnames),
             **_adapter_group(n_adapters, lnames)}
    extra["state_zero_init"] = list(cnames) + list(lnames)
    return Artifact(f"decode_step_{cfg.name}_a{n_adapters}", fn, ins, outs,
                    cfg, extra)


def decode_verify_adapters_artifact(cfg, n_adapters, b=LOGITS_B, s=LOGITS_S,
                                    k=DRAFT_K):
    """Adapter-stacked verify window: per-row `adapter_ix (B,)` routes each
    draft window through its own slot, like the stacked decode step."""
    fn, pnames, lnames, cnames = M.make_decode_verify_adapters(cfg, n_adapters)
    ins = [("tokens", _spec((b, k + 1), jnp.int32)),
           ("pos", _spec((b,), jnp.int32)),
           ("adapter_ix", _spec((b,), jnp.int32))]
    ins += _param_specs(cfg, pnames)
    ins += _stacked_lora_specs(cfg, n_adapters)
    ins += _cache_specs(cfg, b, s)
    outs = ["logits"] + ["new." + n for n in cnames]
    extra = {"kind": "decode_verify", "batch": b, "seq": s, "draft_k": k,
             "param_names": pnames, "lora_names": lnames,
             "cache_names": cnames, **_cache_threading(cnames),
             **_adapter_group(n_adapters, lnames)}
    extra["state_zero_init"] = list(cnames) + list(lnames)
    return Artifact(f"decode_verify_{cfg.name}_a{n_adapters}", fn, ins, outs,
                    cfg, extra)


def decode_prefill_chunk_adapters_artifact(cfg, n_adapters, chunk,
                                           b=LOGITS_B, s=LOGITS_S):
    """Adapter-stacked chunked admission: scalar `adapter_ix` names the
    slot every window of the admitted row forwards under."""
    fn, pnames, lnames, cnames = M.make_decode_prefill_chunk_adapters(
        cfg, n_adapters)
    ins = [("tokens", _spec((1, chunk), jnp.int32)),
           ("start_pos", _spec((), jnp.int32)),
           ("last_pos", _spec((), jnp.int32)),
           ("row_onehot", _spec((b,))),
           ("adapter_ix", _spec((), jnp.int32))]
    ins += _param_specs(cfg, pnames)
    ins += _stacked_lora_specs(cfg, n_adapters)
    ins += _cache_specs(cfg, b, s)
    outs = ["logits"] + ["new." + n for n in cnames]
    extra = {"kind": "decode_prefill_chunk", "batch": b, "seq": s,
             "chunk": chunk, "param_names": pnames, "lora_names": lnames,
             "cache_names": cnames, **_cache_threading(cnames),
             **_adapter_group(n_adapters, lnames)}
    extra["state_zero_init"] = list(cnames) + list(lnames)
    return Artifact(
        f"decode_prefill_chunk_{cfg.name}_a{n_adapters}_c{chunk}", fn, ins,
        outs, cfg, extra)


def adapter_artifacts(cfg, n_adapters, b=LOGITS_B, s=LOGITS_S, k=DRAFT_K):
    """The multi-adapter serving family: stacked logits + the stacked
    decode trio + the stacked chunk ladder, all sharing one adapter slot
    group so the scheduler can mix adapters in a single batch on any
    decode path."""
    return ([logits_adapters_artifact(cfg, n_adapters, b, s),
             decode_prefill_adapters_artifact(cfg, n_adapters, b, s),
             decode_step_adapters_artifact(cfg, n_adapters, b, s),
             decode_verify_adapters_artifact(cfg, n_adapters, b, s, k)]
            + [decode_prefill_chunk_adapters_artifact(cfg, n_adapters, c, b, s)
               for c in chunk_ladder(s)])


def grad_imp_artifact(cfg, b=TRAIN_B, s=TRAIN_S):
    fn, pnames = M.make_grad_importance(cfg)
    ins = [("tokens", _spec((b, s + 1), jnp.int32)),
           ("loss_mask", _spec((b, s)))]
    ins += _param_specs(cfg, pnames)
    return Artifact(f"gradimp_{cfg.name}", fn, ins, ["head_imp", "ff_imp"],
                    cfg, {"kind": "gradimp", "batch": b, "seq": s,
                          "param_names": pnames})


def kernel_demo_artifact(use_pallas: bool):
    """Small logits artifact lowered *through the Pallas kernels* — the
    kernel-path validation target (compared against the jnp path by both
    pytest and the Rust integration test)."""
    cfg = PRESETS["tiny"]
    fn_ref, pnames, lnames = M.make_logits(cfg)

    def fn(tokens, *flat):
        params = dict(zip(pnames, flat[:len(pnames)]))
        lora = dict(zip(lnames, flat[len(pnames):]))
        proj = M.ProjCtx(params, lora=lora, cfg=cfg, use_pallas=use_pallas)
        return (M.forward(cfg, proj, tokens),)

    ins = [("tokens", _spec((2, 32), jnp.int32))]
    ins += _param_specs(cfg, pnames)
    ins += _lora_specs(cfg)
    name = "logits_tiny_pallas" if use_pallas else "logits_tiny_jnp"
    return Artifact(name, fn, ins, ["logits"], cfg,
                    {"kind": "logits", "batch": 2, "seq": 32,
                     "pallas": use_pallas, "param_names": pnames,
                     "lora_names": lnames})


# ---------------------------------------------------------------------------
# Suites
# ---------------------------------------------------------------------------

def build_suite(suite: str):
    arts = []
    P = PRESETS

    def pruned(base, ratio):
        return pruned_config(P[base], ratio)

    if suite in ("smoke", "std"):
        tiny = P["tiny"]
        arts += [pretrain_artifact(tiny, b=2, s=32),
                 sft_artifact(tiny, b=2, s=32),
                 sft_artifact(tiny, masked=True, b=2, s=32),
                 sft_artifact(tiny, quantized=True, b=2, s=32),
                 eval_artifact(tiny, b=2, s=32),
                 logits_artifact(tiny, b=2, s=32),
                 grad_imp_artifact(tiny, b=2, s=32),
                 pretrain_artifact(tiny, masked=True, b=2, s=32),
                 pretrain_artifact(pruned_config(tiny, 0.5), b=2, s=32),
                 sft_artifact(pruned_config(tiny, 0.5), b=2, s=32),
                 sft_artifact(pruned_config(tiny, 0.5), quantized=True, b=2, s=32),
                 eval_artifact(pruned_config(tiny, 0.5), b=2, s=32),
                 kernel_demo_artifact(True),
                 kernel_demo_artifact(False)]
        arts += decode_artifacts(tiny, b=2, s=32)
        # paged mirror of the tiny decode family (block pool + per-row
        # tables, DESIGN.md §2f) — same pool bytes as the dense grid
        arts += decode_paged_artifacts(tiny, b=2, s=32)
        # the pruned proxy's own decode trio (+ its logits artifact): the
        # drafter side of "draft small, verify large" — and a target in its
        # own right for the self-speculative equivalence matrix
        arts += [logits_artifact(pruned_config(tiny, 0.5), b=2, s=32)]
        arts += decode_artifacts(pruned_config(tiny, 0.5), b=2, s=32)
        # multi-adapter serving quartet: batch 4 so a single mixed batch can
        # hold >= 3 distinct adapters (the acceptance scenario)
        arts += adapter_artifacts(tiny, n_adapters=3, b=4, s=32)
    if suite == "std":
        # LLaMA-2 proxy herd --------------------------------------------
        for nm in ("l7b", "l13b", "l70b"):
            cfg = P[nm]
            arts += [pretrain_artifact(cfg), sft_artifact(cfg),
                     eval_artifact(cfg), logits_artifact(cfg)]
            arts += decode_artifacts(cfg)
        # production serving shape: one frozen base, many task adapters
        arts += adapter_artifacts(P["l13b"], n_adapters=4)
        arts += decode_paged_artifacts(P["l13b"])
        arts += [grad_imp_artifact(P["l13b"]), grad_imp_artifact(P["l70b"])]
        # 13B: structured pruned (rand/stru share shapes) + masked variants
        c13p = pruned("l13b", 0.65)
        arts += [pretrain_artifact(c13p), sft_artifact(c13p),
                 eval_artifact(c13p), logits_artifact(c13p)]
        arts += decode_artifacts(c13p)
        arts += [sft_artifact(P["l13b"], masked=True),
                 pretrain_artifact(P["l13b"], masked=True)]
        # 70B: reduction-ratio sweep (fig7/8) + QLoRAM
        for ratio in (0.65, 0.75, 0.85, 0.95):
            cp = pruned("l70b", ratio)
            arts += [pretrain_artifact(cp), sft_artifact(cp, quantized=True),
                     eval_artifact(cp)]
        # LLaMA-3.1 proxy herd (fig5, tab7)
        for nm in ("l8b", "l70b3"):
            cfg = P[nm]
            arts += [pretrain_artifact(cfg), sft_artifact(cfg),
                     eval_artifact(cfg), logits_artifact(cfg)]
            arts += decode_artifacts(cfg)
        arts += [grad_imp_artifact(P["l70b3"])]
        c703p = pruned("l70b3", 0.85)
        arts += [pretrain_artifact(c703p), sft_artifact(c703p, quantized=True),
                 eval_artifact(c703p)]
        # end-to-end ~100M driver
        e2e = P["e2e100m"]
        arts += [pretrain_artifact(e2e, b=4, s=128),
                 eval_artifact(e2e, b=4, s=128)]
    return arts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--suite", default="std", choices=["std", "smoke"])
    ap.add_argument("--only", default=None, help="regex filter on names")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    arts = build_suite(args.suite)
    if args.only:
        pat = re.compile(args.only)
        arts = [a for a in arts if pat.search(a.name)]
    if args.list:
        for a in arts:
            print(a.name)
        return
    os.makedirs(args.out_dir, exist_ok=True)
    print(f"emitting {len(arts)} artifacts to {args.out_dir}", flush=True)
    t0 = time.time()
    for a in arts:
        a.emit(args.out_dir)
    # suite-level manifest for the Rust registry
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump({"suite": args.suite,
                   "artifacts": sorted(a.name for a in arts)}, f, indent=1)
    print(f"done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()

"""NF4 dequantise-and-matmul Pallas kernel — the QLoRAM base-weight path.

Paper Eq. 9: during QLoRAM training the pruned base weight is stored in NF4
(4-bit NormalFloat, blockwise absmax scaling) and dequantised on the fly in
the forward pass:  y = x @ Q⁻¹(W0^P) (+ the LoRA path, fused upstream).

GPU→TPU adaptation (DESIGN.md §Hardware-Adaptation): QLoRA's CUDA kernel
dequantises 4-bit codes in registers ahead of the tensor-core MMA. Here the
(bm, bn) code tile and its (bm, bn/block) absmax tile ride into VMEM
together via paired BlockSpecs; the VPU does the codebook gather + scale and
hands a dense f32 tile to the MXU. The codebook (16 floats) lives in SMEM as
a constant. Codes are carried as int32 in the artifact (the xla 0.1.6
literal bridge has no u4/u8 path) — *storage* accounting uses the packed
4-bit size, see rust/src/quant/.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref
from .tiling import fit_tile, fit_tile_multiple


def _kernel(cb_ref, x_ref, c_ref, s_ref, o_ref, acc_ref, *, block, n_m):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = c_ref[...]
    # Codebook gather on the VPU; the 16-entry table arrives as a dedicated
    # (replicated) input block rather than a captured constant.
    w = cb_ref[...][codes]
    bm, bn = codes.shape
    scale = jnp.repeat(s_ref[...], block, axis=1)
    acc_ref[...] += jnp.dot(x_ref[...], w * scale,
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_m - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "bs", "bn", "bm"))
def nf4_dequant_matmul(x, codes, absmax, block: int = 64,
                       bs: int = 128, bn: int = 128, bm: int = 128):
    """y = x @ dequant_nf4(codes, absmax).

    x (s, m); codes (m, n) int32 in [0,16); absmax (m, n//block).
    bn must be a multiple of `block` so absmax tiles align.
    """
    s, m = x.shape
    n = codes.shape[1]
    bs, bm = fit_tile(s, bs), fit_tile(m, bm)
    bn = fit_tile_multiple(n, bn, block)   # absmax tiles must stay aligned
    assert n % bn == 0 and bn % block == 0
    n_m = m // bm
    grid = (s // bs, n // bn, n_m)
    return pl.pallas_call(
        functools.partial(_kernel, block=block, n_m=n_m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((16,), lambda i, j, k: (0,)),                  # codebook
            pl.BlockSpec((bs, bm), lambda i, j, k: (i, k)),             # x
            pl.BlockSpec((bm, bn), lambda i, j, k: (k, j)),             # codes
            pl.BlockSpec((bm, bn // block), lambda i, j, k: (k, j)),    # absmax
        ],
        out_specs=pl.BlockSpec((bs, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bs, bn), jnp.float32)],
        interpret=True,
    )(ref.NF4_CODEBOOK, x, codes, absmax)


def nf4_dequant_matmul_or_ref(x, codes, absmax, block, use_pallas: bool):
    if use_pallas:
        return nf4_dequant_matmul(x, codes, absmax, block=block)
    return ref.nf4_dequant_matmul_ref(x, codes, absmax, block)

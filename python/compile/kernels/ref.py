"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
is checked against its oracle by `python/tests/test_kernels.py` (hypothesis
shape/dtype sweeps + fixed seeds). They are also the implementations used by
the *fast path* artifacts (DESIGN.md §5): under `interpret=True`, Pallas
kernels lower to per-grid-point loops that are slow on the CPU PJRT backend,
so AOT defaults to these fused-by-XLA formulations and emits kernel-path
variants for validation benches.
"""

import jax.numpy as jnp

# The 16-entry NF4 codebook (QLoRA, Dettmers et al. 2023): quantiles of a
# standard normal, normalised so the extreme codes are ±1.
NF4_CODEBOOK = jnp.array(
    [-1.0, -0.6961928009986877, -0.5250730514526367, -0.39491748809814453,
     -0.28444138169288635, -0.18477343022823334, -0.09105003625154495, 0.0,
     0.07958029955625534, 0.16093020141124725, 0.24611230194568634,
     0.33791524171829224, 0.44070982933044434, 0.5626170039176941,
     0.7229568362236023, 1.0],
    dtype=jnp.float32,
)


def lora_matmul_ref(x, w, a, b, scale):
    """y = x @ w + scale * (x @ a) @ b.

    x: (s, m); w: (m, n); a: (m, r); b: (r, n).
    """
    return x @ w + scale * ((x @ a) @ b)


def masked_lora_matmul_ref(x, w_p, a, b, mask, scale):
    """Non-structured LoRAM forward (paper Eq. 4 with C1/C2):

    y = x @ W0^P + scale * x @ ((A B) ∘ M)

    w_p already contains zeros at pruned positions; the mask is applied to
    the materialised low-rank product so pruned positions receive no update.
    """
    dw = (a @ b) * mask
    return x @ w_p + scale * (x @ dw)


def nf4_dequant_ref(codes, absmax, block: int):
    """Blockwise NF4 dequantisation along the last axis.

    codes: (m, n) int32 in [0, 16); absmax: (m, n // block) per-block scale.
    """
    w = NF4_CODEBOOK[codes]
    scale = jnp.repeat(absmax, block, axis=1)
    return w * scale


def nf4_dequant_matmul_ref(x, codes, absmax, block: int):
    """y = x @ dequant_nf4(codes, absmax)  (QLoRAM base-weight path, Eq. 9)."""
    return x @ nf4_dequant_ref(codes, absmax, block)


def nf4_quantize_ref(w, block: int):
    """Blockwise NF4 quantisation (oracle for the Rust quantizer too).

    Returns (codes int32 (m, n), absmax (m, n//block)).
    """
    m, n = w.shape
    assert n % block == 0
    blocks = w.reshape(m, n // block, block)
    absmax = jnp.max(jnp.abs(blocks), axis=-1)
    safe = jnp.where(absmax == 0, 1.0, absmax)
    normed = blocks / safe[..., None]
    dists = jnp.abs(normed[..., None] - NF4_CODEBOOK[None, None, None, :])
    codes = jnp.argmin(dists, axis=-1).astype(jnp.int32)
    return codes.reshape(m, n), absmax

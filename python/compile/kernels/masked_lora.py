"""Masked LoRA matmul Pallas kernel — the non-structured LoRAM forward.

Paper Eq. 4 with deployment notes C1/C2: under semi-structured (4:8) or
unstructured pruning the base weight keeps its shape with zeros at pruned
positions, and the low-rank update must also be *masked* so pruned positions
receive no update (their gradients are blocked through the same mask).

    y = x @ W0^P + scale * x @ ((A·B) ∘ M)

The mask couples the (m, n) geometry of A·B, so the low-rank product cannot
stay factorised — but it never needs to hit HBM either: this kernel
materialises (A·B)∘M one (bm, bn) VMEM tile at a time, adds it onto the
pruned base tile, and feeds the combined tile through the MXU. HBM traffic
is identical to a plain matmul plus the rank-r factors.

Gradient note: the VJP wrt A/B applies the same mask to the upstream
cotangent (see model.py::masked_lora_proj), implementing C2 exactly.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref
from .tiling import fit_tile


def _kernel(x_ref, w_ref, a_ref, b_ref, m_ref, o_ref, acc_ref, *, scale, n_m):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Materialise the masked low-rank tile in VMEM and fuse into the base tile.
    dw = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    w_eff = w_ref[...].astype(jnp.float32) + scale * dw * m_ref[...]
    acc_ref[...] += jnp.dot(x_ref[...], w_eff,
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_m - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bs", "bn", "bm"))
def masked_lora_matmul(x, w_p, a, b, mask, scale: float = 1.0,
                       bs: int = 128, bn: int = 128, bm: int = 128):
    """y = x@W0^P + scale·x@((A·B)∘M).

    x (s, m); w_p (m, n) pruned base (zeros at pruned entries);
    a (m, r); b (r, n); mask (m, n) in {0, 1}.
    """
    s, m = x.shape
    n = w_p.shape[1]
    r = a.shape[1]
    bs, bn, bm = fit_tile(s, bs), fit_tile(n, bn), fit_tile(m, bm)
    n_m = m // bm
    grid = (s // bs, n // bn, n_m)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, n_m=n_m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, bm), lambda i, j, k: (i, k)),   # x
            pl.BlockSpec((bm, bn), lambda i, j, k: (k, j)),   # w_p
            pl.BlockSpec((bm, r), lambda i, j, k: (k, 0)),    # a
            pl.BlockSpec((r, bn), lambda i, j, k: (0, j)),    # b
            pl.BlockSpec((bm, bn), lambda i, j, k: (k, j)),   # mask
        ],
        out_specs=pl.BlockSpec((bs, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bs, bn), jnp.float32)],
        interpret=True,
    )(x, w_p, a, b, mask)


def masked_lora_matmul_or_ref(x, w_p, a, b, mask, scale, use_pallas: bool):
    if use_pallas:
        return masked_lora_matmul(x, w_p, a, b, mask, scale=float(scale))
    return ref.masked_lora_matmul_ref(x, w_p, a, b, mask, scale)

"""Tile-size selection shared by the Pallas kernels.

Pallas BlockSpecs here require tiles that evenly divide the array dims (no
masking epilogue is implemented). `fit_tile` picks the largest divisor of
`dim` that is <= `target`, preferring multiples of `align` (the TPU lane
granule, 8 sublanes x 128 lanes for f32 — we align to 8 and let the target
default of 128 capture the lane dimension)."""


def fit_tile(dim: int, target: int, align: int = 8) -> int:
    target = min(target, dim)
    best = 1
    for t in range(1, target + 1):
        if dim % t == 0:
            if t % align == 0:
                best = max(best, t)
            elif best % align != 0:
                best = max(best, t)
    # prefer aligned divisors when one exists
    aligned = [t for t in range(align, target + 1, align)
               if dim % t == 0]
    return max(aligned) if aligned else best


def fit_tile_multiple(dim: int, target: int, multiple: int) -> int:
    """Largest divisor of `dim` <= target that is a multiple of `multiple`."""
    target = min(target, dim)
    for t in range(target - target % multiple, 0, -multiple):
        if dim % t == 0:
            return t
    return multiple if dim % multiple == 0 else dim

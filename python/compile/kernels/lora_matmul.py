"""Fused LoRA matmul Pallas kernel: y = x @ W + scale * (x @ A) @ B.

This is the projection-level hot-spot of LoRA/LoRAM training and inference
(paper Eq. 1/4/7): every attention and MLP projection runs it. The fusion
point is the insight worth a kernel — the low-rank update never materialises
W + s·AB in HBM; the rank-r path rides along in registers/VMEM while the
dense W tile streams through the MXU.

TPU mapping (DESIGN.md §Hardware-Adaptation): grid is (s/bs, n/bn, m/bm)
with the contraction axis innermost. Per (i, j) output tile we keep two VMEM
scratch accumulators: the (bs, bn) output tile and the (bs, r) running x·A
product. On the final contraction step the rank-r product is expanded
through B and added — one extra (bs, r)x(r, bn) MXU pass per output tile,
amortised over m/bm contraction steps.

Lowered with interpret=True (CPU PJRT cannot execute Mosaic custom-calls);
the real-TPU tile plan and VMEM budget are estimated in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref
from .tiling import fit_tile


def _kernel(x_ref, w_ref, a_ref, b_ref, o_ref, acc_ref, xa_ref, *, scale, n_m):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xa_ref[...] = jnp.zeros_like(xa_ref)

    x = x_ref[...]
    acc_ref[...] += jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    xa_ref[...] += jnp.dot(x, a_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == n_m - 1)
    def _finish():
        lora = jnp.dot(xa_ref[...], b_ref[...],
                       preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + scale * lora).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bs", "bn", "bm"))
def lora_matmul(x, w, a, b, scale: float = 1.0,
                bs: int = 128, bn: int = 128, bm: int = 128):
    """Fused y = x@W + scale·(x@A)@B. Shapes: x (s,m), w (m,n), a (m,r), b (r,n)."""
    s, m = x.shape
    n = w.shape[1]
    r = a.shape[1]
    bs, bn, bm = fit_tile(s, bs), fit_tile(n, bn), fit_tile(m, bm)
    n_m = m // bm
    grid = (s // bs, n // bn, n_m)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, n_m=n_m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, bm), lambda i, j, k: (i, k)),   # x
            pl.BlockSpec((bm, bn), lambda i, j, k: (k, j)),   # w
            pl.BlockSpec((bm, r), lambda i, j, k: (k, 0)),    # a
            pl.BlockSpec((r, bn), lambda i, j, k: (0, j)),    # b
        ],
        out_specs=pl.BlockSpec((bs, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s, n), x.dtype),
        # VMEM accumulators: output tile + running x·A
        scratch_shapes=[
            pltpu.VMEM((bs, bn), jnp.float32),
            pltpu.VMEM((bs, r), jnp.float32),
        ],
        interpret=True,
    )(x, w, a, b)


def lora_matmul_or_ref(x, w, a, b, scale, use_pallas: bool):
    """Dispatch used by the L2 model: Pallas kernel or the jnp oracle."""
    if use_pallas:
        return lora_matmul(x, w, a, b, scale=float(scale))
    return ref.lora_matmul_ref(x, w, a, b, scale)

"""Model configuration presets shared between the L2 compile path and the
Rust coordinator (exported as JSON next to each artifact).

The proxy family mirrors the LLaMA recipe (RMSNorm, SwiGLU, RoPE, optional
GQA) at a scale trainable on this single-core CPU testbed; the *real*
LLaMA-2/3.1 shape specs used for analytic memory accounting live on the Rust
side (rust/src/memory/), not here.
"""

from dataclasses import dataclass, field, asdict
from typing import List, Optional


@dataclass
class ModelConfig:
    name: str
    vocab_size: int = 512
    d_model: int = 256
    n_layers: int = 8
    n_heads: int = 8
    n_kv_heads: int = 8
    d_ff: int = 688
    max_seq: int = 128
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5
    # LoRA
    lora_rank: int = 8
    lora_alpha: float = 16.0
    lora_lm_head: bool = True  # LLaMA-3 proxies exclude lm_head LoRA (paper §B)
    # Structured pruning plan: per-layer (n_heads_kept, n_kv_heads_kept, d_ff_kept).
    # None = unpruned (full) model.
    layer_plan: Optional[List[List[int]]] = None

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def layer_shapes(self, i: int):
        """(n_heads, n_kv_heads, d_ff) for layer i under the pruning plan."""
        if self.layer_plan is None:
            return (self.n_heads, self.n_kv_heads, self.d_ff)
        h, kv, ff = self.layer_plan[i]
        return (h, kv, ff)

    def param_count(self) -> int:
        n = self.vocab_size * self.d_model  # embed
        hd = self.head_dim
        for i in range(self.n_layers):
            h, kv, ff = self.layer_shapes(i)
            n += self.d_model * (h * hd)          # wq
            n += self.d_model * (kv * hd) * 2     # wk, wv
            n += (h * hd) * self.d_model          # wo
            n += self.d_model * ff * 2            # w_up, w_gate
            n += ff * self.d_model                # w_down
            n += self.d_model * 2                 # two rmsnorm scales
        n += self.d_model                          # final norm
        n += self.d_model * self.vocab_size        # lm_head
        return n

    def to_dict(self):
        return asdict(self)


# ---------------------------------------------------------------------------
# Proxy presets (roles documented in DESIGN.md §4)
# ---------------------------------------------------------------------------

def _mk(name, d, layers, heads, kv, ff, **kw) -> ModelConfig:
    return ModelConfig(name=name, d_model=d, n_layers=layers, n_heads=heads,
                       n_kv_heads=kv, d_ff=ff, **kw)


PRESETS = {
    # LLaMA-2 proxy herd
    "l7b":  _mk("l7b", 192, 6, 6, 6, 512),
    "l13b": _mk("l13b", 256, 8, 8, 8, 688),
    "l70b": _mk("l70b", 384, 12, 12, 4, 1024),
    # LLaMA-3.1 proxy herd (no lm_head LoRA)
    "l8b":  _mk("l8b", 224, 7, 8, 4, 608, lora_lm_head=False),
    "l70b3": _mk("l70b3", 416, 13, 13, 13, 1104, lora_lm_head=False),
    # tiny CI config
    "tiny": _mk("tiny", 64, 2, 2, 2, 160, max_seq=64),
    # end-to-end ~100M validation driver
    "e2e100m": _mk("e2e100m", 768, 12, 12, 12, 2048, vocab_size=512, max_seq=128),
}


def structured_plan(cfg: ModelConfig, ratio: float, protect_first: int,
                    protect_last: int, head_scores=None, ff_scores=None,
                    seed: int = 0) -> List[List[int]]:
    """Build a per-layer kept-shape plan for structured pruning.

    `ratio` is the fraction of parameters *removed* from the prunable middle
    layers (paper's "pruning ratio"). Heads and d_ff channels are removed at
    the same per-layer rate, mirroring LLM-Pruner's uniform block-wise setup.
    The first `protect_first` and last `protect_last` layers are untouched.
    Scores (if given) only reorder *which* channels are kept — counts are
    identical for rand/stru so their reduction ratio matches (paper Tab. 4).
    """
    keep = 1.0 - ratio
    plan = []
    for i in range(cfg.n_layers):
        if i < protect_first or i >= cfg.n_layers - protect_last:
            plan.append([cfg.n_heads, cfg.n_kv_heads, cfg.d_ff])
        else:
            h = max(1, round(cfg.n_heads * keep))
            # keep kv head count in proportion, at least 1, and divide heads
            kv = max(1, round(cfg.n_kv_heads * keep)) if cfg.n_kv_heads != cfg.n_heads else h
            # multiples of 16 keep NF4 block alignment (see aot.NF4_BLOCK)
            ff = max(16, int(round(cfg.d_ff * keep / 16.0)) * 16)
            plan.append([h, kv, ff])
    return plan


def pruned_config(cfg: ModelConfig, ratio: float, protect_first=None,
                  protect_last=None, suffix="p") -> ModelConfig:
    """Derive the pruned (train-time) config from a full config."""
    if protect_first is None:
        protect_first = 4 if cfg.n_layers > 8 else 2
    if protect_last is None:
        protect_last = 2 if cfg.n_layers > 8 else 1
    plan = structured_plan(cfg, ratio, protect_first, protect_last)
    out = ModelConfig(**{**cfg.to_dict(), "name": f"{cfg.name}_{suffix}{int(ratio*100)}",
                         "layer_plan": plan})
    return out

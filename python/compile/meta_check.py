"""Meta-schema validation: the python mirror of `rust/src/runtime/meta.rs`
(+ the Session binding/slot-group resolution rules in `session.rs`).

The Rust runtime is driven entirely by each artifact's `.meta.json`; this
module re-states the rules the Rust side enforces so CI can reject a
misdeclared meta *before* any Rust build exists (this container has no
cargo) and without lowering HLO:

* required fields: name, config (ModelCfg numeric fields), inputs, outputs
* every io entry carries name / shape / dtype in {float32, int32}
* `extra.state_bindings`: source is an output, target is an input,
  shapes/dtypes identical; every `new.*`/`new_m.*`/`new_v.*` output bound
* `extra.state_zero_init`: every name is an input
* `extra.slot_groups` (the adapter group): the declared gather input
  exists (int32), every member is an input whose leading dim == size,
  and members do not repeat across groups
* `extra.kind == "decode_verify"`: `draft_k` >= 1 and the tokens input
  is a (B, draft_k + 1) window (the speculative verify contract)
* `extra.kind == "decode_prefill_chunk"`: `chunk` >= 1 and <= seq, the
  tokens input is a (1, chunk) window, `start_pos`/`last_pos` are scalar
  int32 inputs and `row_onehot` selects the cache row (the chunked
  admission contract, DESIGN.md §2e) — unless the artifact is paged, in
  which case the block table is the row selection
* `extra.paged`: `block_size`/`n_blocks` >= 1, `seq` divides evenly into
  blocks, a `block_table` int32 input of shape (B, seq/block) for
  step/verify or (seq/block,) for the prefill kinds, and every declared
  cache input pooled as (n_blocks, block_size, ...) (the paged decode
  contract, DESIGN.md §2f)

Usage:
    python -m compile.meta_check              # validate smoke+std suites
    python -m compile.meta_check --dir DIR    # + every *.meta.json in DIR
"""

import argparse
import glob
import json
import os
import sys

# mirror of meta.rs::ModelCfg::from_json required numeric fields
CONFIG_FIELDS = ("vocab_size", "d_model", "n_layers", "n_heads",
                 "n_kv_heads", "d_ff", "max_seq", "lora_rank", "lora_alpha")
DTYPES = ("float32", "int32")
STATE_PREFIXES = ("new.", "new_m.", "new_v.")


def _io_map(entries, what, errs):
    out = {}
    for e in entries:
        name = e.get("name")
        if not isinstance(name, str) or not name:
            errs.append(f"{what} entry without a name: {e!r}")
            continue
        if name in out:
            errs.append(f"duplicate {what} '{name}'")
        shape = e.get("shape")
        if not isinstance(shape, list) or \
                not all(isinstance(d, int) and d >= 0 for d in shape):
            errs.append(f"{what} '{name}': bad shape {shape!r}")
            shape = []
        dtype = e.get("dtype", "float32")
        if dtype not in DTYPES:
            errs.append(f"{what} '{name}': unsupported dtype {dtype!r}")
        out[name] = (tuple(shape), dtype)
    return out


def check_meta(meta: dict) -> list:
    """Return a list of schema violations (empty = valid under the Rust
    runtime's rules)."""
    errs = []
    if not isinstance(meta.get("name"), str) or not meta["name"]:
        errs.append("missing meta name")
    cfg = meta.get("config")
    if not isinstance(cfg, dict):
        errs.append("missing config")
    else:
        for k in CONFIG_FIELDS:
            if not isinstance(cfg.get(k), (int, float)):
                errs.append(f"config field {k} missing or non-numeric")
        plan = cfg.get("layer_plan")
        if plan is not None:
            if not isinstance(plan, list) or any(
                    not isinstance(r, list) or len(r) != 3 for r in plan):
                errs.append("layer_plan rows must be [h, kv, ff] triples")
            elif isinstance(cfg.get("n_layers"), (int, float)) and \
                    len(plan) != int(cfg["n_layers"]):
                errs.append(f"layer_plan has {len(plan)} rows for "
                            f"{int(cfg['n_layers'])} layers")
    for key in ("inputs", "outputs"):
        if not isinstance(meta.get(key), list):
            errs.append(f"missing {key}")
            return errs
    inputs = _io_map(meta["inputs"], "input", errs)
    outputs = _io_map(meta["outputs"], "output", errs)
    extra = meta.get("extra") or {}
    if not isinstance(extra, dict):
        errs.append("extra must be an object")
        return errs

    # ---- state bindings (session.rs::resolve_bindings) -------------------
    bindings = extra.get("state_bindings", {})
    if not isinstance(bindings, dict):
        errs.append("state_bindings must be an object")
        bindings = {}
    for out_name, in_name in bindings.items():
        if out_name not in outputs:
            errs.append(f"state binding source '{out_name}' is not an output")
            continue
        if in_name not in inputs:
            errs.append(f"state binding target '{in_name}' is not an input")
            continue
        if outputs[out_name] != inputs[in_name]:
            errs.append(f"binding {out_name} -> {in_name}: "
                        f"{outputs[out_name]} vs {inputs[in_name]}")
    for out_name in outputs:
        if out_name.startswith(STATE_PREFIXES):
            # the naming-convention fallback only fires when the meta
            # declares no bindings at all (old metas); a declared map must
            # cover every state-style output
            if bindings and out_name not in bindings:
                errs.append(f"state output '{out_name}' has no input binding")

    # ---- zero-init (session.rs zero-fill) --------------------------------
    for name in extra.get("state_zero_init", []):
        if name not in inputs:
            errs.append(f"state_zero_init '{name}' is not an input")

    # ---- decode_verify window (meta.rs::draft_k) -------------------------
    if extra.get("kind") == "decode_verify":
        k = extra.get("draft_k")
        # bool is an int subclass in python but not a JSON integer to the
        # Rust mirror (as_usize() rejects it) — keep the gates in lockstep
        if not isinstance(k, int) or isinstance(k, bool) or k < 1:
            errs.append(f"decode_verify: bad draft_k {k!r}")
        elif "tokens" not in inputs:
            errs.append("decode_verify: no tokens input")
        else:
            shape = inputs["tokens"][0]
            if len(shape) != 2 or shape[1] != k + 1:
                errs.append(f"decode_verify: tokens shape {shape} does not "
                            f"hold the draft_k+1 = {k + 1} window")

    # ---- decode_prefill_chunk window (meta.rs::chunk) --------------------
    if extra.get("kind") == "decode_prefill_chunk":
        c = extra.get("chunk")
        if not isinstance(c, int) or isinstance(c, bool) or c < 1:
            errs.append(f"decode_prefill_chunk: bad chunk {c!r}")
        elif "tokens" not in inputs:
            errs.append("decode_prefill_chunk: no tokens input")
        else:
            shape = inputs["tokens"][0]
            if len(shape) != 2 or shape[0] != 1 or shape[1] != c:
                errs.append(f"decode_prefill_chunk: tokens shape {shape} is "
                            f"not the (1, chunk) = (1, {c}) window")
            seq = extra.get("seq")
            if isinstance(seq, int) and c > seq:
                errs.append(f"decode_prefill_chunk: chunk {c} exceeds the "
                            f"{seq}-long cache grid")
        for scalar in ("start_pos", "last_pos"):
            if scalar not in inputs:
                errs.append(f"decode_prefill_chunk: no {scalar} input")
            elif inputs[scalar] != ((), "int32"):
                errs.append(f"decode_prefill_chunk: {scalar} must be a "
                            "scalar int32")
        if "row_onehot" not in inputs and "paged" not in extra:
            errs.append("decode_prefill_chunk: no row_onehot input")

    # ---- paged decode (meta.rs::paged; DESIGN.md §2f) --------------------
    paged = extra.get("paged")
    if paged is not None:
        if not isinstance(paged, dict):
            errs.append("paged must be an object")
            paged = {}
        bs, nb = paged.get("block_size"), paged.get("n_blocks")
        ok = True
        for label, v in (("block_size", bs), ("n_blocks", nb)):
            if not isinstance(v, int) or isinstance(v, bool) or v < 1:
                errs.append(f"paged: bad {label} {v!r}")
                ok = False
        seq = extra.get("seq")
        batch = extra.get("batch")
        kind = extra.get("kind")
        if ok and isinstance(seq, int) and seq % bs != 0:
            errs.append(f"paged: seq {seq} is not a whole number of "
                        f"{bs}-slot blocks")
            ok = False
        if "block_table" not in inputs:
            errs.append("paged: no block_table input")
        elif ok and isinstance(seq, int):
            shape, dtype = inputs["block_table"]
            if dtype != "int32":
                errs.append("paged: block_table must be int32")
            rows = seq // bs
            want = None
            if kind in ("decode_step", "decode_verify"):
                if isinstance(batch, int):
                    want = (batch, rows)
            elif kind in ("decode_prefill", "decode_prefill_chunk"):
                want = (rows,)
            if want is not None and shape != want:
                errs.append(f"paged: block_table shape {list(shape)} != "
                            f"{list(want)} for kind {kind}")
        if ok:
            for cname in extra.get("cache_names", []):
                if cname in inputs:
                    shp = inputs[cname][0]
                    if len(shp) < 2 or shp[0] != nb or shp[1] != bs:
                        errs.append(f"paged: cache '{cname}' shape "
                                    f"{list(shp)} is not pooled "
                                    f"({nb}, {bs}, ...)")

    # ---- slot groups (the adapter group; session.rs::resolve_groups) -----
    groups = extra.get("slot_groups", {})
    if not isinstance(groups, dict):
        errs.append("slot_groups must be an object")
        groups = {}
    seen_members = set()
    for gname, g in groups.items():
        if not isinstance(g, dict):
            errs.append(f"slot group '{gname}' must be an object")
            continue
        size = g.get("size")
        if not isinstance(size, int) or size < 1:
            errs.append(f"slot group '{gname}': bad size {size!r}")
            continue
        gather = g.get("input")
        if gather not in inputs:
            errs.append(f"slot group '{gname}': gather input {gather!r} "
                        "is not an input")
        elif inputs[gather][1] != "int32":
            errs.append(f"slot group '{gname}': gather input '{gather}' "
                        "must be int32")
        members = g.get("members", [])
        if not isinstance(members, list) or not members:
            errs.append(f"slot group '{gname}': empty member list")
            members = []
        for m in members:
            if m in seen_members:
                errs.append(f"slot group member '{m}' repeats across groups")
            seen_members.add(m)
            if m not in inputs:
                errs.append(f"slot group '{gname}': member '{m}' is not "
                            "an input")
            elif not inputs[m][0] or inputs[m][0][0] != size:
                errs.append(f"slot group '{gname}': member '{m}' shape "
                            f"{inputs[m][0]} does not stack {size} slots")
    return errs


def _report(label, errs, bad):
    if errs:
        bad.append(label)
        for e in errs:
            print(f"  FAIL {label}: {e}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=None,
                    help="also validate every *.meta.json in this directory")
    ap.add_argument("--suites", default="smoke,std")
    args = ap.parse_args()
    bad = []
    checked = 0

    suites = [s for s in args.suites.split(",") if s]
    if suites:
        # import lazily: suite validation needs jax (eval_shape), on-disk
        # validation does not
        from . import aot
        for suite in suites:
            for art in aot.build_suite(suite):
                _report(f"{suite}:{art.name}", check_meta(art.meta_dict()), bad)
                checked += 1

    if args.dir:
        metas = sorted(glob.glob(os.path.join(args.dir, "*.meta.json")))
        for path in metas:
            with open(path) as f:
                meta = json.load(f)
            _report(path, check_meta(meta), bad)
            checked += 1

    if bad:
        print(f"meta_check: {len(bad)}/{checked} metas FAILED")
        sys.exit(1)
    print(f"meta_check: {checked} metas OK")


if __name__ == "__main__":
    main()

"""L2: the LLaMA-architecture model with LoRA/LoRAM adapters, in JAX.

This module is build-time only. `aot.py` lowers the functions defined here
to HLO text artifacts; the Rust coordinator (L3) executes them via PJRT and
never imports Python.

Parameter layout
----------------
Parameters travel between Rust and the artifacts as a *flat, ordered list*
of tensors. The canonical order is defined by `param_names(cfg)` /
`lora_names(cfg)` and exported in every artifact's `.meta.json`; Rust packs
its `TensorStore` into PJRT buffers in exactly that order.

Weight convention: every projection is stored as (in_features, out_features)
and applied as `y = x @ W` — matching the L1 kernels.

LoRA convention (paper §2.1, W_Δ = B·A there): here `a` is the (in, r)
down-projection (normal init) and `b` the (r, out) up-projection (zero
init), so W_Δ = a @ b and y += (alpha/r) · (x@a)@b. `recovery` (Eq. 5/6) is
performed host-side in Rust by scattering pruned-shape a/b into full-shape
zeros; the same `logits`/`eval_loss` artifacts then serve base, LoRA and
recovered-LoRAM inference.
"""

import functools
from typing import Dict, List

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.lora_matmul import lora_matmul_or_ref
from .kernels.masked_lora import masked_lora_matmul_or_ref
from .kernels.nf4 import nf4_dequant_matmul_or_ref
from .kernels import ref as kref

# Projections that receive LoRA adapters (paper §2.2: q,k,v,o + gate,up,down
# [+ lm_head for the LLaMA-2 family]).
LAYER_PROJ = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")
# Projections that are NF4-quantised under QLoRAM (linear layers only;
# embeddings and norms stay in full precision, as in QLoRA).
QUANT_PROJ = LAYER_PROJ


# ---------------------------------------------------------------------------
# Parameter naming / shapes
# ---------------------------------------------------------------------------

def layer_proj_shapes(cfg: ModelConfig, i: int) -> Dict[str, tuple]:
    h, kv, ff = cfg.layer_shapes(i)
    hd = cfg.head_dim
    d = cfg.d_model
    return {
        "wq": (d, h * hd),
        "wk": (d, kv * hd),
        "wv": (d, kv * hd),
        "wo": (h * hd, d),
        "w_gate": (d, ff),
        "w_up": (d, ff),
        "w_down": (ff, d),
    }


def param_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    """name -> shape for all base parameters, in canonical order."""
    out: Dict[str, tuple] = {}
    out["embed"] = (cfg.vocab_size, cfg.d_model)
    for i in range(cfg.n_layers):
        out[f"l{i}.attn_norm"] = (cfg.d_model,)
        for k, shp in layer_proj_shapes(cfg, i).items():
            out[f"l{i}.{k}"] = shp
        out[f"l{i}.mlp_norm"] = (cfg.d_model,)
    out["final_norm"] = (cfg.d_model,)
    out["lm_head"] = (cfg.d_model, cfg.vocab_size)
    return out


def lora_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    """name -> shape for LoRA a/b factors, in canonical order."""
    r = cfg.lora_rank
    out: Dict[str, tuple] = {}
    for i in range(cfg.n_layers):
        for k, (m, n) in layer_proj_shapes(cfg, i).items():
            out[f"l{i}.{k}.lora_a"] = (m, r)
            out[f"l{i}.{k}.lora_b"] = (r, n)
    if cfg.lora_lm_head:
        out["lm_head.lora_a"] = (cfg.d_model, r)
        out["lm_head.lora_b"] = (r, cfg.vocab_size)
    return out


def stacked_lora_shapes(cfg: ModelConfig, n_adapters: int) -> Dict[str, tuple]:
    """LoRA shapes with a leading adapter axis: the multi-adapter serving
    artifacts take every factor stacked as (n_adapters, ...) and gather one
    adapter per batch row (see AdapterProjCtx)."""
    return {k: (n_adapters,) + s for k, s in lora_shapes(cfg).items()}


def param_names(cfg: ModelConfig) -> List[str]:
    return list(param_shapes(cfg).keys())


def lora_names(cfg: ModelConfig) -> List[str]:
    return list(lora_shapes(cfg).keys())


def mask_names(cfg: ModelConfig) -> List[str]:
    """Masked (non-structured) variants carry one {0,1} mask per projection."""
    out = []
    for i in range(cfg.n_layers):
        for k in LAYER_PROJ:
            out.append(f"l{i}.{k}.mask")
    return out


def quant_names(cfg: ModelConfig) -> List[str]:
    """QLoRAM: projection weights are replaced by (codes, absmax) pairs."""
    out = []
    for i in range(cfg.n_layers):
        for k in QUANT_PROJ:
            out.append(f"l{i}.{k}.codes")
            out.append(f"l{i}.{k}.absmax")
    return out


def init_params(cfg: ModelConfig, key) -> Dict[str, jax.Array]:
    """Scaled-normal init (GPT-2 style residual scaling on wo/w_down)."""
    shapes = param_shapes(cfg)
    params = {}
    resid_scale = 1.0 / jnp.sqrt(2.0 * cfg.n_layers)
    keys = jax.random.split(key, len(shapes))
    for (name, shp), k in zip(shapes.items(), keys):
        if name.endswith("norm"):
            params[name] = jnp.ones(shp, jnp.float32)
        else:
            std = 0.02
            if name.endswith(".wo") or name.endswith(".w_down"):
                std = 0.02 * resid_scale
            params[name] = std * jax.random.normal(k, shp, jnp.float32)
    return params


def init_lora(cfg: ModelConfig, key) -> Dict[str, jax.Array]:
    shapes = lora_shapes(cfg)
    out = {}
    keys = jax.random.split(key, len(shapes))
    for (name, shp), k in zip(shapes.items(), keys):
        if name.endswith("lora_a"):
            out[name] = jax.random.normal(k, shp, jnp.float32) / jnp.sqrt(shp[0])
        else:
            out[name] = jnp.zeros(shp, jnp.float32)
    return out


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def rope(x, theta):
    """Rotary embeddings. x: (B, S, H, hd)."""
    b, s, h, hd = x.shape
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    pos = jnp.arange(s, dtype=jnp.float32)
    ang = pos[:, None] * freqs[None, :]            # (S, half)
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def rope_at(x, pos, theta):
    """Rotary embedding at explicit per-row positions. x: (B, 1, H, hd),
    pos: (B,) int32 — the grid index each row's token sits at."""
    return rope_at_many(x, pos[:, None], theta)


def rope_at_many(x, pos, theta):
    """Rotary embedding at explicit per-token positions. x: (B, T, H, hd),
    pos: (B, T) int32 — the grid index each token sits at."""
    half = x.shape[-1] // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = pos.astype(jnp.float32)[..., None] * freqs[None, None, :]  # (B,T,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def repeat_kv(x, h):
    """GQA head sharing: (B, S, kv, hd) -> (B, S, h, hd). Pruned head
    counts may not divide, in which case tile-then-trim matches the
    full-forward convention."""
    kv = x.shape[2]
    if kv == h:
        return x
    if h % kv == 0:
        return jnp.repeat(x, h // kv, axis=2)
    return jnp.tile(x, (1, 1, (h + kv - 1) // kv, 1))[:, :, :h]


class ProjCtx:
    """How a projection multiplies its input — dense, masked, or quantised.

    One ProjCtx per artifact variant; chooses the L1 kernel (or its oracle)
    per projection and wires LoRA through the C2 gradient mask when needed.
    """

    def __init__(self, params, lora=None, masks=None, quant=None,
                 cfg: ModelConfig = None, use_pallas: bool = False,
                 nf4_block: int = 16):
        self.p = params
        self.lora = lora or {}
        self.masks = masks or {}
        self.quant = quant or {}
        self.cfg = cfg
        self.use_pallas = use_pallas
        self.nf4_block = nf4_block
        self.scale = cfg.lora_alpha / cfg.lora_rank

    def lm_head(self, x):
        """Final projection: (B, T, D) -> (B, T, V), optional lm_head LoRA."""
        b, t, d = x.shape
        if self.lora.get("lm_head.lora_a") is not None:
            x2 = x.reshape(-1, d)
            logits = lora_matmul_or_ref(
                x2, self.p["lm_head"], self.lora["lm_head.lora_a"],
                self.lora["lm_head.lora_b"], self.scale, self.use_pallas)
            return logits.reshape(b, t, -1)
        return x @ self.p["lm_head"]

    def __call__(self, x, name):
        """x: (..., in) -> (..., out) for projection `name` (e.g. 'l3.wq')."""
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        a = self.lora.get(f"{name}.lora_a")
        mask = self.masks.get(f"{name}.mask")
        codes = self.quant.get(f"{name}.codes")
        if codes is not None:
            absmax = self.quant[f"{name}.absmax"]
            y = nf4_dequant_matmul_or_ref(x2, codes, absmax, self.nf4_block,
                                          self.use_pallas)
            if a is not None:
                b = self.lora[f"{name}.lora_b"]
                if mask is not None:
                    y = y + self.scale * (x2 @ ((a @ b) * mask))
                else:
                    y = y + self.scale * ((x2 @ a) @ b)
        else:
            w = self.p[name]
            if a is not None:
                b = self.lora[f"{name}.lora_b"]
                if mask is not None:
                    y = masked_lora_matmul_or_ref(x2, w, a, b, mask,
                                                  self.scale, self.use_pallas)
                else:
                    y = lora_matmul_or_ref(x2, w, a, b, self.scale,
                                           self.use_pallas)
            else:
                y = x2 @ w
        return y.reshape(*lead, y.shape[-1])


class AdapterProjCtx:
    """Projection context over a *stack* of adapters (punica-style).

    LoRA factors arrive stacked along a leading adapter axis —
    `a (n_adapters, in, r)`, `b (n_adapters, r, out)` — and `adapter_ix
    (B,)` selects one adapter per batch row, so a single compiled artifact
    serves a heterogeneous-adapter batch: y[i] = x[i] @ W + s·(x[i] @
    a[ix[i]]) @ b[ix[i]]. Inputs must keep their batch axis ((B, T, in),
    never flattened); the base path is dense only (serving-side context:
    masks/quant never meet the stacked inference artifacts).
    """

    def __init__(self, params, lora, adapter_ix, cfg: ModelConfig):
        self.p = params
        self.lora = lora
        self.ix = adapter_ix
        self.cfg = cfg
        self.scale = cfg.lora_alpha / cfg.lora_rank

    def _delta(self, x, a, b):
        a_sel = a[self.ix]                            # (B, in, r)
        b_sel = b[self.ix]                            # (B, r, out)
        xa = jnp.einsum("bti,bir->btr", x, a_sel)
        return jnp.einsum("btr,bro->bto", xa, b_sel)

    def lm_head(self, x):
        y = x @ self.p["lm_head"]
        a = self.lora.get("lm_head.lora_a")
        if a is not None:
            y = y + self.scale * self._delta(x, a, self.lora["lm_head.lora_b"])
        return y

    def __call__(self, x, name):
        """x: (B, T, in) -> (B, T, out) for projection `name`."""
        y = x @ self.p[name]
        a = self.lora.get(f"{name}.lora_a")
        if a is not None:
            y = y + self.scale * self._delta(x, a, self.lora[f"{name}.lora_b"])
        return y


def lm_head_logits(proj, x):
    """Final projection: (B, T, D) -> (B, T, V) under the context's own
    LoRA handling (plain fused path or stacked-adapter gather)."""
    return proj.lm_head(x)


def forward_kv(cfg: ModelConfig, proj: ProjCtx, tokens):
    """Full causal forward that also returns the per-layer post-RoPE K/V
    (pre-GQA-repeat) — exactly the contents a decode cache must hold.
    tokens (B, S) int32 -> (logits (B, S, V), [K_i (B, S, kv_i, hd)],
    [V_i (B, S, kv_i, hd)])."""
    p = proj.p
    x = p["embed"][tokens]                          # (B, S, D)
    b, s, d = x.shape
    hd = cfg.head_dim
    causal = jnp.tril(jnp.ones((s, s), jnp.bool_))
    ks, vs = [], []
    for i in range(cfg.n_layers):
        h, kv, _ = cfg.layer_shapes(i)
        xin = rmsnorm(x, p[f"l{i}.attn_norm"], cfg.rms_eps)
        q = proj(xin, f"l{i}.wq").reshape(b, s, h, hd)
        k = proj(xin, f"l{i}.wk").reshape(b, s, kv, hd)
        v = proj(xin, f"l{i}.wv").reshape(b, s, kv, hd)
        q = rope(q, cfg.rope_theta)
        k = rope(k, cfg.rope_theta)
        ks.append(k)
        vs.append(v)
        kk = repeat_kv(k, h)
        vv = repeat_kv(v, h)
        att = jnp.einsum("bshd,bthd->bhst", q, kk) / jnp.sqrt(float(hd))
        att = jnp.where(causal[None, None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        out = jnp.einsum("bhst,bthd->bshd", att, vv).reshape(b, s, h * hd)
        x = x + proj(out, f"l{i}.wo")
        xin = rmsnorm(x, p[f"l{i}.mlp_norm"], cfg.rms_eps)
        gate = proj(xin, f"l{i}.w_gate")
        up = proj(xin, f"l{i}.w_up")
        x = x + proj(jax.nn.silu(gate) * up, f"l{i}.w_down")
    x = rmsnorm(x, p["final_norm"], cfg.rms_eps)
    return lm_head_logits(proj, x), ks, vs


def forward(cfg: ModelConfig, proj: ProjCtx, tokens):
    """tokens (B, S) int32 -> logits (B, S, V). The K/V capture in
    `forward_kv` is dead code here and DCE'd away when lowering."""
    return forward_kv(cfg, proj, tokens)[0]


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def token_nll(logits, targets, loss_mask):
    """Per-sequence (sum NLL, token count). logits (B,S,V); targets (B,S)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    nll = nll * loss_mask
    return nll.sum(axis=-1), loss_mask.sum(axis=-1)


def mean_loss(logits, targets, loss_mask):
    s, c = token_nll(logits, targets, loss_mask)
    return s.sum() / jnp.maximum(c.sum(), 1.0)


# ---------------------------------------------------------------------------
# Adam (hand-rolled; optax is not available in this image)
# ---------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def adam_update(grads, params, m, v, step, lr):
    """One Adam step over aligned dicts. `step` is the 1-based step count."""
    b1t = ADAM_B1 ** step
    b2t = ADAM_B2 ** step
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        mk = ADAM_B1 * m[k] + (1 - ADAM_B1) * g
        vk = ADAM_B2 * v[k] + (1 - ADAM_B2) * g * g
        mhat = mk / (1 - b1t)
        vhat = vk / (1 - b2t)
        new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
        new_m[k] = mk
        new_v[k] = vk
    return new_p, new_m, new_v


# ---------------------------------------------------------------------------
# Artifact entry points (lowered by aot.py)
# ---------------------------------------------------------------------------

def make_init_fn(cfg: ModelConfig):
    def init_fn(seed):
        key = jax.random.PRNGKey(seed)
        kp, kl = jax.random.split(key)
        params = init_params(cfg, kp)
        lora = init_lora(cfg, kl)
        return (tuple(params[k] for k in param_names(cfg))
                + tuple(lora[k] for k in lora_names(cfg)))
    return init_fn


def make_pretrain_step(cfg: ModelConfig, masked=False, use_pallas=False):
    """Full-parameter LM step: pre-training *and* alignment (Eq. 8).

    With `masked=True` (non-structured LoRAM alignment) the projection
    gradients are multiplied by the pruning mask so pruned positions stay
    exactly zero through continual pre-training.
    """
    pnames = param_names(cfg)
    mnames = mask_names(cfg) if masked else []

    def step_fn(step, lr, tokens, loss_mask, *flat):
        n = len(pnames)
        params = dict(zip(pnames, flat[:n]))
        m = dict(zip(pnames, flat[n:2 * n]))
        v = dict(zip(pnames, flat[2 * n:3 * n]))
        masks = dict(zip(mnames, flat[3 * n:3 * n + len(mnames)]))

        def loss_fn(ps):
            proj = ProjCtx(ps, cfg=cfg, use_pallas=use_pallas)
            logits = forward(cfg, proj, tokens[:, :-1])
            return mean_loss(logits, tokens[:, 1:], loss_mask)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        if masked:
            for key, msk in masks.items():
                wname = key[:-len(".mask")]
                grads[wname] = grads[wname] * msk
        new_p, new_m, new_v = adam_update(grads, params, m, v, step, lr)
        return ((loss,)
                + tuple(new_p[k] for k in pnames)
                + tuple(new_m[k] for k in pnames)
                + tuple(new_v[k] for k in pnames))
    return step_fn, pnames, mnames


def make_sft_step(cfg: ModelConfig, masked=False, quantized=False,
                  use_pallas=False, nf4_block=16):
    """LoRA SFT step: Adam on a/b only; base frozen (dense, masked or NF4)."""
    pnames = param_names(cfg)
    lnames = lora_names(cfg)
    mnames = mask_names(cfg) if masked else []
    qnames = quant_names(cfg) if quantized else []
    if quantized:
        pnames = [p for p in pnames
                  if not any(p.endswith("." + q) for q in QUANT_PROJ)]

    def step_fn(step, lr, tokens, loss_mask, *flat):
        i = 0
        params = dict(zip(pnames, flat[i:i + len(pnames)])); i += len(pnames)
        quant = dict(zip(qnames, flat[i:i + len(qnames)])); i += len(qnames)
        masks = dict(zip(mnames, flat[i:i + len(mnames)])); i += len(mnames)
        lora = dict(zip(lnames, flat[i:i + len(lnames)])); i += len(lnames)
        m = dict(zip(lnames, flat[i:i + len(lnames)])); i += len(lnames)
        v = dict(zip(lnames, flat[i:i + len(lnames)])); i += len(lnames)

        def loss_fn(lr_params):
            proj = ProjCtx(params, lora=lr_params, masks=masks, quant=quant,
                           cfg=cfg, use_pallas=use_pallas, nf4_block=nf4_block)
            logits = forward(cfg, proj, tokens[:, :-1])
            return mean_loss(logits, tokens[:, 1:], loss_mask)

        loss, grads = jax.value_and_grad(loss_fn)(lora)
        new_l, new_m, new_v = adam_update(grads, lora, m, v, step, lr)
        return ((loss,)
                + tuple(new_l[k] for k in lnames)
                + tuple(new_m[k] for k in lnames)
                + tuple(new_v[k] for k in lnames))
    return step_fn, pnames, qnames, mnames, lnames


def make_eval_loss(cfg: ModelConfig, with_lora=True, use_pallas=False):
    """Per-sequence (sum NLL, count) — perplexity and option scoring."""
    pnames = param_names(cfg)
    lnames = lora_names(cfg) if with_lora else []

    def eval_fn(tokens, loss_mask, *flat):
        params = dict(zip(pnames, flat[:len(pnames)]))
        lora = dict(zip(lnames, flat[len(pnames):]))
        proj = ProjCtx(params, lora=lora, cfg=cfg, use_pallas=use_pallas)
        logits = forward(cfg, proj, tokens[:, :-1])
        s, c = token_nll(logits, tokens[:, 1:], loss_mask)
        return (s, c)
    return eval_fn, pnames, lnames


def make_logits(cfg: ModelConfig, with_lora=True, use_pallas=False):
    """Full-sequence logits; Rust slices positions for decoding/sampling."""
    pnames = param_names(cfg)
    lnames = lora_names(cfg) if with_lora else []

    def logits_fn(tokens, *flat):
        params = dict(zip(pnames, flat[:len(pnames)]))
        lora = dict(zip(lnames, flat[len(pnames):]))
        proj = ProjCtx(params, lora=lora, cfg=cfg, use_pallas=use_pallas)
        return (forward(cfg, proj, tokens),)
    return logits_fn, pnames, lnames


# ---------------------------------------------------------------------------
# KV-cache decode (DESIGN.md §2a: the incremental serving hot path)
# ---------------------------------------------------------------------------

def kv_cache_shapes(cfg: ModelConfig, b: int, s: int) -> Dict[str, tuple]:
    """name -> shape for the per-layer decode caches, in canonical order.

    Caches hold post-RoPE, pre-GQA-repeat keys/values — one (B, S, kv_i,
    hd) pair per layer, so pruned layer plans shrink their caches too.
    """
    out: Dict[str, tuple] = {}
    hd = cfg.head_dim
    for i in range(cfg.n_layers):
        _, kv, _ = cfg.layer_shapes(i)
        out[f"cache_k.l{i}"] = (b, s, kv, hd)
        out[f"cache_v.l{i}"] = (b, s, kv, hd)
    return out


def kv_cache_names(cfg: ModelConfig) -> List[str]:
    return list(kv_cache_shapes(cfg, 1, 1).keys())


def make_decode_prefill(cfg: ModelConfig, with_lora=True, use_pallas=False):
    """Cache-filling prefill for one row of the decode grid.

    Runs the full causal forward over a single (1, S) padded prompt, then
    writes the computed per-layer K/V into the (B, S, ...) cache tensors
    at the row selected by `row_onehot`; every other row's cache passes
    through untouched, so admission never perturbs in-flight rows. Also
    returns the logits at `last_pos` (the prompt token that predicts the
    first generated one). The cache outputs are declared as donated state
    (aot state_bindings), so on the device backend they stay in PJRT
    buffers across calls — the decode analogue of optimiser-state
    threading in training artifacts.
    """
    pnames = param_names(cfg)
    lnames = lora_names(cfg) if with_lora else []
    cnames = kv_cache_names(cfg)

    def prefill_fn(tokens, last_pos, row_onehot, *flat):
        i = 0
        params = dict(zip(pnames, flat[i:i + len(pnames)])); i += len(pnames)
        lora = dict(zip(lnames, flat[i:i + len(lnames)])); i += len(lnames)
        caches = dict(zip(cnames, flat[i:i + len(cnames)]))
        proj = ProjCtx(params, lora=lora, cfg=cfg, use_pallas=use_pallas)
        return prefill_scatter(cfg, proj, tokens, last_pos, row_onehot, caches)
    return prefill_fn, pnames, lnames, cnames


def cached_window_forward(cfg: ModelConfig, proj, tokens, abspos, caches,
                          row_onehot=None, block_table=None):
    """THE cached layer loop: every decode-family forward is one call here.

    `tokens (B_f, T)` int32 and `abspos (B_f, T)` int32 give each token's
    grid position; `caches` maps name -> (B, S, kv_i, hd). Token t of row b
    writes its post-RoPE K/V at grid slot `abspos[b, t]` and attends over
    cache positions <= abspos[b, t] *after* the window's write (causal
    within the window, earlier cache before it). Off-grid positions
    (abspos >= S) write nothing — the scatter one-hot is empty — which is
    the dummy-row/padded-tail convention every caller relies on.

    Three scatter regimes:
    * `row_onehot=None` — batched (B_f == B): step (T=1) and the verify
      window (T=K+1); each row writes into its own cache row.
    * `row_onehot (B,)` — single-row window (B_f == 1): chunked prefill,
      of which the monolithic prefill is the start_pos=0, C=S special
      case; the window scatters into the selected cache row only (every
      other row — and every untouched slot of the selected row — passes
      through bitwise) and attends over that row's post-write cache.
    * `block_table (B_f, S/block)` int32 — paged (DESIGN.md §2f): caches
      are one pooled `(n_blocks, block, kv_i, hd)` tensor shared by all
      rows; logical position p of row b lives at physical slot
      `block_table[b, p // block] * block + p % block`. The scatter is
      physical-slot-indexed, the attention gathers the row's logical
      (S, kv, hd) view from the post-write pool, and everything after the
      gather is the dense code path — which is why paged and dense greedy
      streams are byte-identical. Host contract: distinct rows' write
      positions map to distinct physical blocks (the BlockPool CoW-forks
      shared blocks before any write), and table entries beyond a row's
      frontier may be garbage — they are only ever read under the `valid`
      mask (reads clamp, writes past the logical grid scatter nowhere).
      `row_onehot` does not combine with paging: the table *is* the row
      selection.

    Returns `(x (B_f, T, D) post-final-norm, {name: new cache})`; callers
    pick their own lm_head slice (full window, frontier, or `last_pos`).
    """
    assert row_onehot is None or block_table is None
    p = proj.p
    x = p["embed"][tokens]                       # (B_f, T, D)
    b_f, t = tokens.shape
    hd = cfg.head_dim
    if block_table is None:
        s = next(iter(caches.values())).shape[1]
    else:
        nb, blk = next(iter(caches.values())).shape[:2]
        nslots = nb * blk
        s = block_table.shape[1] * blk
    grid = jnp.arange(s, dtype=jnp.int32)
    valid = grid[None, None, :] <= abspos[:, :, None]  # (B_f, T, S)
    if block_table is None:
        # scatter one-hot: token t lands at grid slot abspos[:, t];
        # off-grid tokens produce no write at all
        write = (abspos[:, :, None] == grid[None, None, :]).astype(jnp.float32)
        taken = write.sum(axis=1)                # (B_f, S): rewritten slots
    else:
        # physical-slot one-hot: abspos -> table-mapped pool slot; tokens
        # past the logical grid map to slot `nslots`, i.e. nowhere
        blk_ix = jnp.clip(abspos // blk, 0, block_table.shape[1] - 1)
        phys_blk = jnp.take_along_axis(block_table, blk_ix, axis=1)
        phys = phys_blk * blk + abspos % blk              # (B_f, T)
        phys = jnp.where(abspos < s, phys, nslots)
        slots = jnp.arange(nslots, dtype=jnp.int32)
        write = (phys[:, :, None] == slots[None, None, :]).astype(jnp.float32)
        taken = write.sum(axis=(0, 1))           # (N,): disjoint across rows
        tbl = jnp.clip(block_table, 0, nb - 1)   # reads clamp garbage tails
    if row_onehot is not None:
        sel = row_onehot[:, None, None, None]    # (B, 1, 1, 1)
        hit = taken[:, :, None, None]            # (1, S, 1, 1)
    new_caches = {}
    for li in range(cfg.n_layers):
        h, kv, _ = cfg.layer_shapes(li)
        xin = rmsnorm(x, p[f"l{li}.attn_norm"], cfg.rms_eps)
        q = proj(xin, f"l{li}.wq").reshape(b_f, t, h, hd)
        k = proj(xin, f"l{li}.wk").reshape(b_f, t, kv, hd)
        v = proj(xin, f"l{li}.wv").reshape(b_f, t, kv, hd)
        q = rope_at_many(q, abspos, cfg.rope_theta)
        k = rope_at_many(k, abspos, cfg.rope_theta)
        ck = caches[f"cache_k.l{li}"]
        cv = caches[f"cache_v.l{li}"]
        if block_table is not None:
            pool_k = ck.reshape(nslots, kv, hd)
            pool_v = cv.reshape(nslots, kv, hd)
            keep = (1.0 - taken)[:, None, None]          # (N, 1, 1)
            npk = pool_k * keep + jnp.einsum("btn,btch->nch", write, k)
            npv = pool_v * keep + jnp.einsum("btn,btch->nch", write, v)
            nk = npk.reshape(nb, blk, kv, hd)
            nv = npv.reshape(nb, blk, kv, hd)
            # each row's logical (S, kv, hd) view, gathered post-write
            row_k = nk[tbl].reshape(b_f, s, kv, hd)
            row_v = nv[tbl].reshape(b_f, s, kv, hd)
        elif row_onehot is None:
            keep = (1.0 - taken)[:, :, None, None]       # (B, S, 1, 1)
            nk = ck * keep + jnp.einsum("bts,btnh->bsnh", write, k)
            nv = cv * keep + jnp.einsum("bts,btnh->bsnh", write, v)
            row_k, row_v = nk, nv
        else:
            win_k = jnp.einsum("ts,tnh->snh", write[0], k[0])[None]
            win_v = jnp.einsum("ts,tnh->snh", write[0], v[0])[None]
            nk = ck * (1.0 - sel * hit) + sel * win_k
            nv = cv * (1.0 - sel * hit) + sel * win_v
            # attention runs over the selected row *after* this window's
            # write: earlier chunks' cached K/V plus the causal window
            row_k = jnp.einsum("b,bsnh->snh", row_onehot, nk)[None]
            row_v = jnp.einsum("b,bsnh->snh", row_onehot, nv)[None]
        new_caches[f"cache_k.l{li}"] = nk
        new_caches[f"cache_v.l{li}"] = nv
        kk = repeat_kv(row_k, h)                 # (B_f, S, h, hd)
        vv = repeat_kv(row_v, h)
        att = jnp.einsum("bthd,bshd->bhts", q, kk) / jnp.sqrt(float(hd))
        att = jnp.where(valid[:, None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        out = jnp.einsum("bhts,bshd->bthd", att, vv).reshape(b_f, t, h * hd)
        x = x + proj(out, f"l{li}.wo")
        xin = rmsnorm(x, p[f"l{li}.mlp_norm"], cfg.rms_eps)
        gate = proj(xin, f"l{li}.w_gate")
        up = proj(xin, f"l{li}.w_up")
        x = x + proj(jax.nn.silu(gate) * up, f"l{li}.w_down")
    x = rmsnorm(x, p["final_norm"], cfg.rms_eps)
    return x, new_caches


def prefill_scatter(cfg: ModelConfig, proj, tokens, last_pos, row_onehot,
                    caches):
    """Shared prefill tail: forward one (1, S) row, scatter its K/V into the
    `row_onehot`-selected cache row (all other rows pass through), return
    the row's `last_pos` logits followed by the new caches in name order.

    The monolithic prefill IS the chunk window at start_pos = 0, C = S —
    one body, two artifact shapes."""
    return prefill_chunk_scatter(cfg, proj, tokens,
                                 jnp.asarray(0, jnp.int32), last_pos,
                                 row_onehot, caches)


def make_decode_step(cfg: ModelConfig, with_lora=True, use_pallas=False):
    """One (B, 1) incremental decode step over donated K/V caches.

    `tokens` holds each row's frontier token and `pos` its grid index; the
    step writes that token's K/V into the cache at `pos`, attends over
    cache positions <= pos only, and returns next-token logits per row.
    Rows beyond their cache frontier (free, finished, or mid-chunked-
    admission) ride along as dummies fed `pos >= S`: the (grid == pos)
    scatter is empty off-grid, so a dummy writes nothing. (An on-grid
    dummy pos would corrupt a partially chunk-admitted row — chunked
    re-admission rewrites only prompt positions, never the whole row.)
    Cache outputs donate back onto their inputs.
    """
    pnames = param_names(cfg)
    lnames = lora_names(cfg) if with_lora else []
    cnames = kv_cache_names(cfg)

    def step_fn(tokens, pos, *flat):
        i = 0
        params = dict(zip(pnames, flat[i:i + len(pnames)])); i += len(pnames)
        lora = dict(zip(lnames, flat[i:i + len(lnames)])); i += len(lnames)
        caches = dict(zip(cnames, flat[i:i + len(cnames)]))
        proj = ProjCtx(params, lora=lora, cfg=cfg, use_pallas=use_pallas)
        logits, new_caches = decode_step_forward(cfg, proj, tokens, pos, caches)
        return (logits,) + tuple(new_caches[n] for n in cnames)
    return step_fn, pnames, lnames, cnames


def decode_step_forward(cfg: ModelConfig, proj, tokens, pos, caches):
    """Shared (B, 1) incremental forward: writes each row's frontier K/V at
    `pos`, attends over cache positions <= pos, returns ((B, V) logits,
    {name: new cache}). The T = 1 case of `cached_window_forward`."""
    x, new_caches = cached_window_forward(cfg, proj, tokens, pos[:, None],
                                          caches)
    return lm_head_logits(proj, x)[:, 0], new_caches


def make_decode_verify(cfg: ModelConfig, with_lora=True, use_pallas=False):
    """One (B, K+1) verification forward over donated K/V caches — the
    K-position generalization of `make_decode_step` (speculative decoding,
    DESIGN.md §2d).

    Each row feeds its frontier token followed by K draft candidates;
    token t of row b sits at grid position `pos[b] + t`. The forward
    writes all K+1 tokens' K/V at their positions, attends causally
    within the window (position p attends over cache entries <= p), and
    returns logits at *every* window position — logits[:, t] predicts the
    token after candidate t, so one call scores a whole draft run. Rows
    past their frontier feed `pos >= S`: such windows write nothing (the
    scatter one-hot is empty off-grid) and their logits are garbage the
    caller discards. Cache outputs donate back onto their inputs exactly
    like the decode step's.
    """
    pnames = param_names(cfg)
    lnames = lora_names(cfg) if with_lora else []
    cnames = kv_cache_names(cfg)

    def verify_fn(tokens, pos, *flat):
        i = 0
        params = dict(zip(pnames, flat[i:i + len(pnames)])); i += len(pnames)
        lora = dict(zip(lnames, flat[i:i + len(lnames)])); i += len(lnames)
        caches = dict(zip(cnames, flat[i:i + len(cnames)]))
        proj = ProjCtx(params, lora=lora, cfg=cfg, use_pallas=use_pallas)
        logits, new_caches = decode_verify_forward(cfg, proj, tokens, pos,
                                                  caches)
        return (logits,) + tuple(new_caches[n] for n in cnames)
    return verify_fn, pnames, lnames, cnames


def decode_verify_forward(cfg: ModelConfig, proj, tokens, pos, caches):
    """Shared (B, T) windowed incremental forward (T = K+1): writes token t
    of row b at grid position pos[b]+t, attends over cache positions <=
    pos[b]+t, returns ((B, T, V) logits, {name: new cache}).

    The T = K+1 case of `cached_window_forward`.
    """
    t = tokens.shape[1]
    abspos = pos[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]  # (B, T)
    x, new_caches = cached_window_forward(cfg, proj, tokens, abspos, caches)
    return lm_head_logits(proj, x), new_caches   # (B, T, V)


# ---------------------------------------------------------------------------
# Chunked prefill (DESIGN.md §2e: admission without the full-grid stall)
# ---------------------------------------------------------------------------

def make_decode_prefill_chunk(cfg: ModelConfig, with_lora=True,
                              use_pallas=False):
    """Cache-filling prefill for one (1, C) *window* of a prompt.

    The chunked generalization of `make_decode_prefill`: instead of one
    monolithic (1, S) forward padded to the full grid, admission feeds the
    prompt as windows of C tokens. Window token t sits at grid position
    `start_pos + t`; its K/V is scattered into the `row_onehot`-selected
    cache row at start_pos..start_pos+C (off-grid tails write nothing,
    like the verify window), attention sees that row's cached positions
    <= the query position (earlier chunks + the causal window), and the
    logits at window index `last_pos` come back — only the final chunk's
    are meaningful; intermediate chunks are pure cache fills. Caches stay
    donated state exactly as in the monolithic prefill.
    """
    pnames = param_names(cfg)
    lnames = lora_names(cfg) if with_lora else []
    cnames = kv_cache_names(cfg)

    def chunk_fn(tokens, start_pos, last_pos, row_onehot, *flat):
        i = 0
        params = dict(zip(pnames, flat[i:i + len(pnames)])); i += len(pnames)
        lora = dict(zip(lnames, flat[i:i + len(lnames)])); i += len(lnames)
        caches = dict(zip(cnames, flat[i:i + len(cnames)]))
        proj = ProjCtx(params, lora=lora, cfg=cfg, use_pallas=use_pallas)
        return prefill_chunk_scatter(cfg, proj, tokens, start_pos, last_pos,
                                     row_onehot, caches)
    return chunk_fn, pnames, lnames, cnames


def prefill_chunk_scatter(cfg: ModelConfig, proj, tokens, start_pos, last_pos,
                          row_onehot, caches):
    """Shared chunked-prefill tail: forward one (1, C) prompt window whose
    token t sits at grid position start_pos + t, scatter its K/V into the
    `row_onehot`-selected cache row at those positions (every other row —
    and every untouched slot of the selected row — passes through), and
    return the logits at window index `last_pos` followed by the new
    caches in name order. The `row_onehot` case of `cached_window_forward`."""
    c = tokens.shape[1]
    abspos = (start_pos + jnp.arange(c, dtype=jnp.int32))[None]    # (1, C)
    x, new_caches = cached_window_forward(cfg, proj, tokens, abspos, caches,
                                          row_onehot=row_onehot)
    # only the `last_pos` position's logits are ever read (and only on the
    # final chunk): gather before the LM head so intermediate cache-fill
    # chunks skip the (C, V) projection — the window's largest matmul
    row_x = jnp.take(x[0], last_pos, axis=0)[None, None]           # (1, 1, D)
    row_logits = lm_head_logits(proj, row_x)[:, 0]                 # (1, V)
    return (row_logits,) + tuple(new_caches[n] for n in kv_cache_names(cfg))


# ---------------------------------------------------------------------------
# Paged KV cache (DESIGN.md §2f: block pool + per-row block tables)
# ---------------------------------------------------------------------------

def paged_cache_shapes(cfg: ModelConfig, n_blocks: int,
                       block: int) -> Dict[str, tuple]:
    """name -> shape for the pooled per-layer decode caches.

    The paged analogue of `kv_cache_shapes`: instead of one dense
    (B, S, kv_i, hd) slab per layer, all rows share one
    (n_blocks, block, kv_i, hd) pool; a per-row block table maps logical
    positions onto pool blocks, so concurrent-row capacity is bounded by
    pool bytes over *actual* sequence lengths, not batch x max-S.
    """
    out: Dict[str, tuple] = {}
    hd = cfg.head_dim
    for i in range(cfg.n_layers):
        _, kv, _ = cfg.layer_shapes(i)
        out[f"cache_k.l{i}"] = (n_blocks, block, kv, hd)
        out[f"cache_v.l{i}"] = (n_blocks, block, kv, hd)
    return out


def decode_step_paged_forward(cfg: ModelConfig, proj, tokens, pos,
                              block_table, caches):
    """Paged (B, 1) incremental forward: identical to `decode_step_forward`
    except each row's cache slots are resolved through its `block_table`
    row into the shared pool. Off-grid dummies (`pos >= S`) still write
    nothing."""
    x, new_caches = cached_window_forward(cfg, proj, tokens, pos[:, None],
                                          caches, block_table=block_table)
    return lm_head_logits(proj, x)[:, 0], new_caches


def decode_verify_paged_forward(cfg: ModelConfig, proj, tokens, pos,
                                block_table, caches):
    """Paged (B, T) verify window (T = K+1): `decode_verify_forward` with
    pool-resolved cache slots."""
    t = tokens.shape[1]
    abspos = pos[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]  # (B, T)
    x, new_caches = cached_window_forward(cfg, proj, tokens, abspos, caches,
                                          block_table=block_table)
    return lm_head_logits(proj, x), new_caches   # (B, T, V)


def prefill_chunk_paged_scatter(cfg: ModelConfig, proj, tokens, start_pos,
                                last_pos, block_table, caches):
    """Paged chunked-prefill tail: one (1, C) window whose K/V lands in the
    pool blocks named by the admitted row's `(S/block,)` table. Unlike the
    dense chunk there is no `row_onehot` — the table IS the row selection
    (it names that row's physical blocks and nobody else's), so admission
    can never perturb in-flight rows by construction."""
    c = tokens.shape[1]
    abspos = (start_pos + jnp.arange(c, dtype=jnp.int32))[None]    # (1, C)
    x, new_caches = cached_window_forward(cfg, proj, tokens, abspos, caches,
                                          block_table=block_table[None])
    row_x = jnp.take(x[0], last_pos, axis=0)[None, None]           # (1, 1, D)
    row_logits = lm_head_logits(proj, row_x)[:, 0]                 # (1, V)
    return (row_logits,) + tuple(new_caches[n] for n in kv_cache_names(cfg))


def prefill_paged_scatter(cfg: ModelConfig, proj, tokens, last_pos,
                          block_table, caches):
    """Paged monolithic prefill: the start_pos = 0, C = S special case of
    `prefill_chunk_paged_scatter` — same unification as the dense pair."""
    return prefill_chunk_paged_scatter(cfg, proj, tokens,
                                       jnp.asarray(0, jnp.int32), last_pos,
                                       block_table, caches)


def _make_paged(cfg: ModelConfig, with_lora, use_pallas, head, tail_fn):
    """Shared factory plumbing for the paged decode family: unflatten
    params/lora/pooled-caches and dispatch to `tail_fn` with the `head`
    positional inputs in front."""
    pnames = param_names(cfg)
    lnames = lora_names(cfg) if with_lora else []
    cnames = kv_cache_names(cfg)

    def fn(*args):
        lead, flat = args[:head], args[head:]
        i = 0
        params = dict(zip(pnames, flat[i:i + len(pnames)])); i += len(pnames)
        lora = dict(zip(lnames, flat[i:i + len(lnames)])); i += len(lnames)
        caches = dict(zip(cnames, flat[i:i + len(cnames)]))
        proj = ProjCtx(params, lora=lora, cfg=cfg, use_pallas=use_pallas)
        return tail_fn(proj, lead, caches, cnames)
    return fn, pnames, lnames, cnames


def make_decode_prefill_paged(cfg: ModelConfig, with_lora=True,
                              use_pallas=False):
    """Paged `make_decode_prefill`: (tokens (1, S), last_pos, block_table
    (S/block,), params..., lora..., pooled caches...)."""
    def tail(proj, lead, caches, cnames):
        tokens, last_pos, block_table = lead
        return prefill_paged_scatter(cfg, proj, tokens, last_pos,
                                     block_table, caches)
    return _make_paged(cfg, with_lora, use_pallas, 3, tail)


def make_decode_step_paged(cfg: ModelConfig, with_lora=True,
                           use_pallas=False):
    """Paged `make_decode_step`: (tokens (B, 1), pos (B,), block_table
    (B, S/block), params..., lora..., pooled caches...)."""
    def tail(proj, lead, caches, cnames):
        tokens, pos, block_table = lead
        logits, new_caches = decode_step_paged_forward(
            cfg, proj, tokens, pos, block_table, caches)
        return (logits,) + tuple(new_caches[n] for n in cnames)
    return _make_paged(cfg, with_lora, use_pallas, 3, tail)


def make_decode_verify_paged(cfg: ModelConfig, with_lora=True,
                             use_pallas=False):
    """Paged `make_decode_verify`: (tokens (B, K+1), pos (B,), block_table
    (B, S/block), params..., lora..., pooled caches...)."""
    def tail(proj, lead, caches, cnames):
        tokens, pos, block_table = lead
        logits, new_caches = decode_verify_paged_forward(
            cfg, proj, tokens, pos, block_table, caches)
        return (logits,) + tuple(new_caches[n] for n in cnames)
    return _make_paged(cfg, with_lora, use_pallas, 3, tail)


def make_decode_prefill_chunk_paged(cfg: ModelConfig, with_lora=True,
                                    use_pallas=False):
    """Paged `make_decode_prefill_chunk`: (tokens (1, C), start_pos,
    last_pos, block_table (S/block,), params..., lora..., pooled
    caches...)."""
    def tail(proj, lead, caches, cnames):
        tokens, start_pos, last_pos, block_table = lead
        return prefill_chunk_paged_scatter(cfg, proj, tokens, start_pos,
                                           last_pos, block_table, caches)
    return _make_paged(cfg, with_lora, use_pallas, 4, tail)


# ---------------------------------------------------------------------------
# Multi-adapter serving (DESIGN.md §2c: the adapter slot group)
# ---------------------------------------------------------------------------

def make_logits_adapters(cfg: ModelConfig, n_adapters: int):
    """Full-sequence logits over a stack of adapters: LoRA factors arrive
    stacked (n_adapters, ...) and `adapter_ix (B,)` selects one adapter per
    batch row, so one compiled artifact serves heterogeneous batches."""
    pnames = param_names(cfg)
    lnames = lora_names(cfg)

    def logits_fn(tokens, adapter_ix, *flat):
        params = dict(zip(pnames, flat[:len(pnames)]))
        lora = dict(zip(lnames, flat[len(pnames):]))
        proj = AdapterProjCtx(params, lora, adapter_ix, cfg)
        return (forward(cfg, proj, tokens),)
    return logits_fn, pnames, lnames


def make_decode_prefill_adapters(cfg: ModelConfig, n_adapters: int):
    """Adapter-stacked prefill: like `make_decode_prefill` plus a scalar
    `adapter_ix` naming the adapter slot the admitted row decodes under."""
    pnames = param_names(cfg)
    lnames = lora_names(cfg)
    cnames = kv_cache_names(cfg)

    def prefill_fn(tokens, last_pos, row_onehot, adapter_ix, *flat):
        i = 0
        params = dict(zip(pnames, flat[i:i + len(pnames)])); i += len(pnames)
        lora = dict(zip(lnames, flat[i:i + len(lnames)])); i += len(lnames)
        caches = dict(zip(cnames, flat[i:i + len(cnames)]))
        # the forward runs one (1, S) row: broadcast the scalar to (1,)
        proj = AdapterProjCtx(params, lora, adapter_ix[None], cfg)
        return prefill_scatter(cfg, proj, tokens, last_pos, row_onehot, caches)
    return prefill_fn, pnames, lnames, cnames


def make_decode_step_adapters(cfg: ModelConfig, n_adapters: int):
    """Adapter-stacked decode step: `adapter_ix (B,)` routes every row's
    LoRA contribution through its own adapter slot each step."""
    pnames = param_names(cfg)
    lnames = lora_names(cfg)
    cnames = kv_cache_names(cfg)

    def step_fn(tokens, pos, adapter_ix, *flat):
        i = 0
        params = dict(zip(pnames, flat[i:i + len(pnames)])); i += len(pnames)
        lora = dict(zip(lnames, flat[i:i + len(lnames)])); i += len(lnames)
        caches = dict(zip(cnames, flat[i:i + len(cnames)]))
        proj = AdapterProjCtx(params, lora, adapter_ix, cfg)
        logits, new_caches = decode_step_forward(cfg, proj, tokens, pos, caches)
        return (logits,) + tuple(new_caches[n] for n in cnames)
    return step_fn, pnames, lnames, cnames


def make_decode_verify_adapters(cfg: ModelConfig, n_adapters: int):
    """Adapter-stacked verify window: `adapter_ix (B,)` routes every row's
    draft window through its own adapter slot, completing the stacked
    decode pair into a trio."""
    pnames = param_names(cfg)
    lnames = lora_names(cfg)
    cnames = kv_cache_names(cfg)

    def verify_fn(tokens, pos, adapter_ix, *flat):
        i = 0
        params = dict(zip(pnames, flat[i:i + len(pnames)])); i += len(pnames)
        lora = dict(zip(lnames, flat[i:i + len(lnames)])); i += len(lnames)
        caches = dict(zip(cnames, flat[i:i + len(cnames)]))
        proj = AdapterProjCtx(params, lora, adapter_ix, cfg)
        logits, new_caches = decode_verify_forward(cfg, proj, tokens, pos,
                                                  caches)
        return (logits,) + tuple(new_caches[n] for n in cnames)
    return verify_fn, pnames, lnames, cnames


def make_decode_prefill_chunk_adapters(cfg: ModelConfig, n_adapters: int):
    """Adapter-stacked chunked prefill: like `make_decode_prefill_chunk`
    plus a scalar `adapter_ix` naming the slot every window of the
    admitted row forwards under."""
    pnames = param_names(cfg)
    lnames = lora_names(cfg)
    cnames = kv_cache_names(cfg)

    def chunk_fn(tokens, start_pos, last_pos, row_onehot, adapter_ix, *flat):
        i = 0
        params = dict(zip(pnames, flat[i:i + len(pnames)])); i += len(pnames)
        lora = dict(zip(lnames, flat[i:i + len(lnames)])); i += len(lnames)
        caches = dict(zip(cnames, flat[i:i + len(cnames)]))
        # the forward runs one (1, C) window: broadcast the scalar to (1,)
        proj = AdapterProjCtx(params, lora, adapter_ix[None], cfg)
        return prefill_chunk_scatter(cfg, proj, tokens, start_pos, last_pos,
                                     row_onehot, caches)
    return chunk_fn, pnames, lnames, cnames


def make_grad_importance(cfg: ModelConfig):
    """LLM-Pruner-style first-order importance on a calibration batch.

    Returns per-layer head importance (L, n_heads) and per-layer MLP channel
    importance (L, d_ff), aggregated as Σ|w·∂w| over each head/channel group.
    Only valid for the *full* (unpruned) config.
    """
    pnames = param_names(cfg)
    hd = cfg.head_dim

    def imp_fn(tokens, loss_mask, *flat):
        params = dict(zip(pnames, flat))

        def loss_fn(ps):
            proj = ProjCtx(ps, cfg=cfg)
            logits = forward(cfg, proj, tokens[:, :-1])
            return mean_loss(logits, tokens[:, 1:], loss_mask)

        grads = jax.grad(loss_fn)(params)
        head_imp, ff_imp = [], []
        for i in range(cfg.n_layers):
            acc = jnp.zeros((cfg.n_heads,), jnp.float32)
            for nm in ("wq", "wo"):
                w = params[f"l{i}.{nm}"]
                g = grads[f"l{i}.{nm}"]
                s = jnp.abs(w * g)
                if nm == "wq":
                    s = s.reshape(cfg.d_model, cfg.n_heads, hd).sum((0, 2))
                else:
                    s = s.reshape(cfg.n_heads, hd, cfg.d_model).sum((1, 2))
                acc = acc + s
            # kv projections score kv-head groups; spread to query heads
            kvacc = jnp.zeros((cfg.n_kv_heads,), jnp.float32)
            for nm in ("wk", "wv"):
                w = params[f"l{i}.{nm}"]
                g = grads[f"l{i}.{nm}"]
                s = jnp.abs(w * g).reshape(cfg.d_model, cfg.n_kv_heads, hd)
                kvacc = kvacc + s.sum((0, 2))
            rep = cfg.n_heads // cfg.n_kv_heads
            acc = acc + jnp.repeat(kvacc, rep)
            head_imp.append(acc)
            f = jnp.zeros((cfg.d_ff,), jnp.float32)
            for nm, ax in (("w_gate", 0), ("w_up", 0), ("w_down", 1)):
                w = params[f"l{i}.{nm}"]
                g = grads[f"l{i}.{nm}"]
                f = f + jnp.abs(w * g).sum(axis=ax)
            ff_imp.append(f)
        return (jnp.stack(head_imp), jnp.stack(ff_imp))
    return imp_fn, pnames

"""panic-surface: no panics in the serving hot paths.

Non-test code in the hot-path modules (the files the scheduler, the KV
cache, and the session layer execute per tick) must not contain
`.unwrap()`, `.expect(...)`, `panic!`, `unreachable!`, `todo!`,
`unimplemented!`, or slice-index expressions — any of these takes the
whole serving batch down when it fires. `Result` propagation (the files
already return `anyhow::Result` almost everywhere) or a
`// lint: allow(panic, "reason")` annotation with a real reason are the
two ways out. `#[cfg(test)]` / `#[test]` code is exempt.

This is the static mirror of the clippy policy (`clippy.toml` +
`#![cfg_attr(not(test), deny(clippy::unwrap_used, ...))]` in the same
modules) that the first session with a real toolchain inherits.
"""

from .report import Violation
from .rustsrc import find_index_sites, norm_line

RULE = "panic-surface"

# repo-relative hot-path modules (the serving tick's execution surface)
HOT_PATHS = (
    "rust/src/serve.rs",
    "rust/src/coordinator/kvcache.rs",
    "rust/src/coordinator/generate.rs",
    "rust/src/coordinator/speculative.rs",
    "rust/src/coordinator/adapters.rs",
    "rust/src/runtime/session.rs",
)

PANIC_MACROS = ("panic", "unreachable", "todo", "unimplemented")


def _violation(rf, relpath, line, kind, detail, out):
    if rf.allow(line, RULE):
        return
    key = f"{kind}@{norm_line(rf.line_text(line))}"
    msg = f"{detail} in non-test hot-path code"
    if rf.bare_allow(line, RULE):
        msg += " (its lint:allow has no reason — reasons are required)"
    out.append(Violation(RULE, relpath, line, key, msg))


def run(ctx):
    out = []
    for relpath in ctx.config.get("hot_paths", HOT_PATHS):
        rf = ctx.rust_file(relpath)
        if rf is None:
            continue
        code = rf.code
        for i, t in enumerate(code):
            if rf.is_test_line(t.line):
                continue
            if t.kind != "ident":
                continue
            nxt = code[i + 1] if i + 1 < len(code) else None
            prev = code[i - 1] if i > 0 else None
            if (
                t.text in ("unwrap", "expect")
                and prev is not None
                and prev.text == "."
                and nxt is not None
                and nxt.text == "("
            ):
                _violation(rf, relpath, t.line, t.text, f".{t.text}()", out)
            elif (
                t.text in PANIC_MACROS
                and nxt is not None
                and nxt.text == "!"
                # `core::panic!` etc. still match on the final ident;
                # `panic` as a plain ident (e.g. a field) does not
            ):
                _violation(rf, relpath, t.line, t.text, f"{t.text}!", out)
        for line, recv in find_index_sites(code, is_test_line=rf.is_test_line):
            _violation(
                rf, relpath, line, "index", f"slice-index `{recv}[..]`", out
            )
    return out

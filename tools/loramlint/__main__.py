"""Entry point: `python3 tools/loramlint <rust_src>` or
`python3 tools/loramlint/__main__.py <rust_src>` — both work in a bare
stdlib environment (the direct-file form bootstraps sys.path so the
package-relative imports resolve)."""

import os
import sys

if __package__ in (None, ""):
    sys.path.insert(
        0, os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    )
    from loramlint.cli import main
else:
    from .cli import main

sys.exit(main())

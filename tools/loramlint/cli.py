"""loramlint driver: load sources once, run passes, ratchet, report.

Usage (from the repo root, bare stdlib python3):

    python3 tools/loramlint/__main__.py rust/src
    python3 tools/loramlint/__main__.py rust/src --update-baseline
    python3 tools/loramlint/__main__.py rust/src --select panic-surface --json
    python3 tools/loramlint/__main__.py rust/src --locks

Exit codes: 0 clean against the committed baseline; 1 new violations or
stale baseline entries (the ratchet fails in BOTH directions); 2 usage.
"""

import argparse
import json
import os
import sys

from . import (
    contract_mirror,
    lock_discipline,
    panic_surface,
    report,
    result_hygiene,
    trace_coverage,
)
from .rustsrc import RustFile

PASSES = (
    ("panic-surface", panic_surface.run),
    ("contract-mirror", contract_mirror.run),
    ("trace-coverage", trace_coverage.run),
    ("lock-discipline", lock_discipline.run),
    ("result-hygiene", result_hygiene.run),
)


class Context:
    """What every pass sees: parsed rust files, raw texts, config, and a
    scratch `artifacts` dict (the lock pass publishes its acquisition-
    order table there)."""

    def __init__(self, repo, rust_files, config=None):
        self.repo = repo  # absolute repo root
        self.rust_files = rust_files  # relpath -> RustFile (the scan set)
        self.config = config or {}
        self.artifacts = {}
        self._texts = {}

    def read(self, relpath):
        """Raw text of any repo file ('/'-separated relpath), or None."""
        if relpath not in self._texts:
            path = os.path.join(self.repo, *relpath.split("/"))
            try:
                with open(path, encoding="utf-8") as f:
                    self._texts[relpath] = f.read()
            except OSError:
                self._texts[relpath] = None
        return self._texts[relpath]

    def rust_file(self, relpath):
        """Parsed RustFile for `relpath`, loading lazily if it was outside
        the scanned tree (e.g. rust/benches)."""
        if relpath in self.rust_files:
            return self.rust_files[relpath]
        text = self.read(relpath)
        if text is None:
            return None
        rf = RustFile(relpath, text)
        self.rust_files[relpath] = rf
        return rf


def collect_rust_files(repo, rust_src_dir):
    """relpath -> RustFile for every .rs under `rust_src_dir`."""
    root = os.path.join(repo, *rust_src_dir.split("/"))
    if not os.path.isdir(root):
        raise SystemExit(f"loramlint: not a directory: {root}")
    out = {}
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames.sort()
        for name in sorted(filenames):
            if not name.endswith(".rs"):
                continue
            path = os.path.join(dirpath, name)
            rel = os.path.relpath(path, repo).replace(os.sep, "/")
            out[rel] = RustFile.from_path(path)
    return out


def run_passes(ctx, select=None):
    violations = []
    for name, run in PASSES:
        if select and name not in select:
            continue
        violations.extend(run(ctx))
    return violations


def _default_repo():
    # tools/loramlint/cli.py -> two levels above tools/
    return os.path.dirname(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    )


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="loramlint",
        description="stdlib static-analysis suite for the loram Rust stack",
    )
    ap.add_argument(
        "rust_src", nargs="?", default="rust/src",
        help="repo-relative rust source dir to scan (default: rust/src)",
    )
    ap.add_argument(
        "--repo", default=_default_repo(),
        help="repo root (default: inferred from this file's location)",
    )
    ap.add_argument(
        "--baseline", default=None,
        help="ratchet baseline path (default: tools/loramlint/baseline.json)",
    )
    ap.add_argument(
        "--update-baseline", action="store_true",
        help="regenerate the baseline from this scan and exit 0",
    )
    ap.add_argument(
        "--no-baseline", action="store_true",
        help="ignore the baseline: report every violation (exit 1 if any)",
    )
    ap.add_argument(
        "--select", default=None,
        help="comma-separated pass names to run (default: all)",
    )
    ap.add_argument(
        "--json", action="store_true", dest="as_json",
        help="emit machine-readable JSON instead of text",
    )
    ap.add_argument(
        "--locks", action="store_true",
        help="print the lock-acquisition-order table and exit",
    )
    args = ap.parse_args(argv)

    repo = os.path.abspath(args.repo)
    baseline_path = args.baseline or os.path.join(
        repo, "tools", "loramlint", "baseline.json"
    )
    select = None
    if args.select:
        select = set(args.select.split(","))
        known = {name for name, _ in PASSES}
        bad = select - known
        if bad:
            ap.error(f"unknown pass(es): {sorted(bad)}; known: {sorted(known)}")
    if args.locks:
        select = {"lock-discipline"}

    ctx = Context(repo, collect_rust_files(repo, args.rust_src))
    violations = run_passes(ctx, select)

    if args.locks:
        table = ctx.artifacts.get("lock_order_table", {})
        if args.as_json:
            print(json.dumps(table, indent=1, sort_keys=True))
        else:
            print("lock/borrow acquisition order (per fn, non-test):")
            for qual in sorted(table):
                print(f"  {qual}: {' -> '.join(table[qual])}")
        return 0

    if args.update_baseline:
        report.write_baseline(baseline_path, violations)
        counts, _ = report.aggregate(violations)
        total = sum(sum(c.values()) for c in counts.values())
        print(
            f"loramlint: baseline regenerated at {baseline_path} "
            f"({total} ratcheted violation(s) across {len(counts)} "
            "rule/file pair(s))"
        )
        return 0

    if args.no_baseline:
        new, stale = violations, []
    else:
        doc = report.load_baseline(baseline_path)
        new, stale = report.check_against_baseline(violations, doc)

    if args.as_json:
        print(
            json.dumps(
                {
                    "new_violations": [v.to_json() for v in new],
                    "stale_baseline": stale,
                    "scanned_files": sorted(ctx.rust_files),
                    "total_current": len(violations),
                },
                indent=1,
            )
        )
    else:
        for v in sorted(new, key=lambda v: (v.file, v.line, v.rule)):
            print(f"{v.file}:{v.line}: [{v.rule}] {v.msg}")
        for s in stale:
            print(f"STALE: {s}")
        if new or stale:
            print(
                f"\nloramlint: FAIL — {len(new)} new violation(s), "
                f"{len(stale)} stale baseline entr(y/ies). New code must "
                "fix the site or carry `// lint: allow(<rule>, \"reason\")`; "
                "fixed sites must shrink the baseline (--update-baseline)."
            )
        else:
            print(
                f"loramlint: OK — {len(ctx.rust_files)} file(s), "
                f"{len(violations)} baselined violation(s), 0 new, 0 stale"
            )
    return 1 if (new or stale) else 0

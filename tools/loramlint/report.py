"""Violations + the ratchet baseline for loramlint.

A violation's identity is (rule, file, key) where `key` is a
whitespace-collapsed fingerprint of the offending source line — stable
across unrelated edits (line numbers shift; line *content* only changes
when the site itself is touched). Identical lines aggregate by count.

The committed baseline (`tools/loramlint/baseline.json`) is a ratchet:

  * current count >  baseline count  ->  NEW violation, CI fails;
  * current count <  baseline count  ->  STALE baseline entry, CI fails
    too — the baseline must be regenerated (``--update-baseline``) so it
    only ever shrinks; a fixed site can never quietly regress later.

Rules with no baseline entries (the contract-mirror pass ships none)
therefore fail on *any* violation — the ratchet generalizes "zero
tolerance" without a special case.
"""

import json
import os
from collections import Counter


class Violation:
    __slots__ = ("rule", "file", "line", "key", "msg")

    def __init__(self, rule, file, line, key, msg):
        self.rule = rule
        self.file = file  # repo-relative, '/'-separated
        self.line = line
        self.key = key
        self.msg = msg

    def __repr__(self):
        return f"{self.file}:{self.line}: [{self.rule}] {self.msg}"

    def to_json(self):
        return {
            "rule": self.rule,
            "file": self.file,
            "line": self.line,
            "key": self.key,
            "msg": self.msg,
        }


def aggregate(violations):
    """(rule, file) -> Counter{key: count} plus (rule, file, key) -> [lines]."""
    counts = {}
    lines = {}
    for v in violations:
        counts.setdefault((v.rule, v.file), Counter())[v.key] += 1
        lines.setdefault((v.rule, v.file, v.key), []).append(v.line)
    return counts, lines


def load_baseline(path):
    if not os.path.exists(path):
        return {"version": 1, "ratchet": {}}
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "ratchet" not in doc:
        raise SystemExit(f"{path}: not a loramlint baseline (no 'ratchet' key)")
    return doc


def baseline_counts(doc):
    """Flatten the baseline doc to {(rule, file): Counter{key: count}}."""
    out = {}
    for rule, files in doc.get("ratchet", {}).items():
        for file, keys in files.items():
            out[(rule, file)] = Counter(
                {k: int(c) for k, c in keys.items()}
            )
    return out


def write_baseline(path, violations):
    """Regenerate the baseline from the current scan (sorted, stable)."""
    counts, _ = aggregate(violations)
    ratchet = {}
    for (rule, file), keys in sorted(counts.items()):
        ratchet.setdefault(rule, {})[file] = {
            k: keys[k] for k in sorted(keys)
        }
    doc = {"version": 1, "ratchet": ratchet}
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    return doc


def check_against_baseline(violations, baseline_doc):
    """Return (new, stale): `new` is a list of Violations over the
    baselined count; `stale` is a list of human strings naming baseline
    entries the current scan no longer reaches."""
    counts, lines = aggregate(violations)
    base = baseline_counts(baseline_doc)
    new = []
    stale = []
    all_pairs = set(counts) | set(base)
    for pair in sorted(all_pairs):
        rule, file = pair
        cur = counts.get(pair, Counter())
        b = base.get(pair, Counter())
        for key in sorted(set(cur) | set(b)):
            c, want = cur[key], b[key]
            if c > want:
                # surface the newest `c - want` sites (all lines listed —
                # which of N identical lines is "new" is unknowable)
                where = lines[(rule, file, key)]
                for ln in where[: c - want]:
                    new.append(
                        Violation(
                            rule,
                            file,
                            ln,
                            key,
                            f"new violation ({c} > baseline {want}): {key}"
                            + (
                                f" [also at lines {where}]"
                                if len(where) > 1
                                else ""
                            ),
                        )
                    )
            elif c < want:
                stale.append(
                    f"{file}: [{rule}] baseline lists {want} x '{key}' but "
                    f"the scan found {c} — the site was fixed; shrink the "
                    "baseline (run with --update-baseline and commit it)"
                )
    return new, stale

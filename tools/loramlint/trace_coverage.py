"""trace-coverage: state transitions must emit their trace events.

PR 7's observability contract is only as good as the emission sites: a
refactor that moves row admission out of `Server::admit` without moving
the `emit(|| Event::Admit ...)` leaves `trace_report.py` auditing a
stream that silently stopped carrying admissions. This pass pins the
coverage statically:

  1. REQUIRED table — every state-transition fn (admit / evict / rewind
     / finish / requeue / block lifecycle / verify round / session run)
     must exist (a rename fails the lint, forcing the table — and the
     reader's mental model — to move with the code) and its body must
     construct each listed `Event::<Kind>`.
  2. Kind liveness — every kind in `trace.rs::KINDS` must be constructed
     somewhere in non-test rust/src code, and every constructed kind
     must be in `KINDS` (the compiler would catch the latter; we have no
     compiler in this container).

`// lint: allow(trace, "reason")` on the `fn` line is the escape hatch
for a transition that is genuinely ledger-only (its emitting caller is
then named in the reason).
"""

import re

from .report import Violation

RULE = "trace-coverage"

# (file, impl-type, fn, (required Event kinds...))
REQUIRED = (
    ("rust/src/serve.rs", "Server", "enqueue_slo", ("Enqueue",)),
    ("rust/src/serve.rs", "Server", "admit", ("Admit", "Requeue", "Reject")),
    # step's Preempt is the forced-admission pool-pressure requeue; the
    # scheduler-initiated eviction lives in Server::preempt
    (
        "rust/src/serve.rs",
        "Server",
        "step",
        ("DecodeStep", "Finish", "Reject", "Preempt", "DeadlineMiss"),
    ),
    ("rust/src/serve.rs", "Server", "preempt", ("Preempt",)),
    ("rust/src/serve.rs", "Server", "cancel_expired", ("Cancel",)),
    # §2j failure domains: a row fault must leave a Fault + (Retry or
    # terminal Failed) pair, and every health transition must be visible
    (
        "rust/src/serve.rs",
        "Server",
        "fault_row",
        ("Fault", "Preempt", "Retry", "Failed"),
    ),
    ("rust/src/serve.rs", "Server", "set_health", ("Degrade", "Recover")),
    ("rust/src/serve.rs", "Server", "fail_everything", ("Fault", "Failed")),
    ("rust/src/serve.rs", "Server", "fail_queue", ("Failed",)),
    ("rust/src/serve.rs", "Server", "sample_gauges", ("Gauge",)),
    ("rust/src/serve.rs", "SimEngine", "prefill_tick", ("PrefillWindow",)),
    ("rust/src/serve.rs", "SimEngine", "decode_step", ("VerifyRound",)),
    ("rust/src/serve.rs", "SimEngine", "take", ("Evict",)),
    ("rust/src/coordinator/kvcache.rs", "BlockPool", "alloc", ("BlockAlloc",)),
    ("rust/src/coordinator/kvcache.rs", "BlockPool", "release", ("BlockFree",)),
    ("rust/src/coordinator/kvcache.rs", "BlockPool", "evict", ("BlockFree",)),
    ("rust/src/coordinator/kvcache.rs", "BlockPool", "cow", ("CowCopy",)),
    ("rust/src/coordinator/kvcache.rs", "PagedKv", "plan_admit", ("PrefixHit",)),
    ("rust/src/coordinator/kvcache.rs", "KvDecoder", "prefill_chunk", ("PrefillWindow",)),
    ("rust/src/coordinator/kvcache.rs", "KvDecoder", "rewind", ("Rewind",)),
    ("rust/src/coordinator/kvcache.rs", "KvDecoder", "evict", ("Evict",)),
    ("rust/src/coordinator/speculative.rs", "SpecDecoder", "round", ("VerifyRound",)),
    ("rust/src/runtime/session.rs", "Session", "run", ("SessionRun",)),
)

_KINDS_RE = re.compile(r"pub const KINDS[^=]*=\s*&\[(.*?)\];", re.S)


def _body_event_kinds(fn):
    """Event kinds constructed in a fn body: idents following `Event ::`."""
    kinds = set()
    code = fn.body
    for i, t in enumerate(code):
        if t.kind == "ident" and t.text == "Event":
            if (
                i + 3 < len(code)
                and code[i + 1].text == ":"
                and code[i + 2].text == ":"
                and code[i + 3].kind == "ident"
            ):
                kinds.add(code[i + 3].text)
    return kinds


def _has_emit(fn):
    code = fn.body
    for i, t in enumerate(code):
        if (
            t.kind == "ident"
            and t.text == "emit"
            and i + 1 < len(code)
            and code[i + 1].text == "("
        ):
            return True
    return False


def run(ctx):
    out = []
    required = ctx.config.get("trace_required", REQUIRED)
    for relpath, impl, fname, kinds in required:
        rf = ctx.rust_file(relpath)
        if rf is None:
            out.append(
                Violation(
                    RULE, relpath, 0, f"missing-file@{relpath}",
                    f"trace-coverage target file missing: {relpath}",
                )
            )
            continue
        qual = f"{impl}::{fname}"
        matches = [f for f in rf.fns if f.qual == qual and not f.is_test]
        if not matches:
            out.append(
                Violation(
                    RULE, relpath, 0, f"missing-fn@{qual}",
                    f"state-transition fn `{qual}` not found — renamed or "
                    "moved? update trace_coverage.REQUIRED with the new "
                    "emission site",
                )
            )
            continue
        for fn in matches:
            if rf.allow(fn.start_line, RULE):
                continue
            got = _body_event_kinds(fn)
            if not _has_emit(fn):
                out.append(
                    Violation(
                        RULE, relpath, fn.start_line, f"no-emit@{qual}",
                        f"`{qual}` mutates request/row state but contains "
                        f"no emit( call (expected {', '.join(kinds)})",
                    )
                )
                continue
            for kind in kinds:
                if kind not in got:
                    out.append(
                        Violation(
                            RULE, relpath, fn.start_line,
                            f"missing-kind@{qual}:{kind}",
                            f"`{qual}` no longer constructs "
                            f"Event::{kind} — its lifecycle transition "
                            "would vanish from the trace",
                        )
                    )

    # -- kind liveness across the tree ------------------------------------
    trace_rs = ctx.config.get("trace_rs", "rust/src/obs/trace.rs")
    rf = ctx.rust_file(trace_rs)
    if rf is None:
        out.append(
            Violation(RULE, trace_rs, 0, "missing-file@trace.rs",
                      f"{trace_rs} not found — KINDS liveness unchecked")
        )
        return out
    m = _KINDS_RE.search(rf.src)
    if not m:
        out.append(
            Violation(RULE, trace_rs, 0, "missing-kinds-const",
                      "`pub const KINDS` not found in trace.rs")
        )
        return out
    declared = set(re.findall(r'"(\w+)"', m.group(1)))
    constructed = {}  # kind -> first (file, line)
    for relpath, f in ctx.rust_files.items():
        if relpath == trace_rs or "/obs/" in relpath:
            continue  # the obs subsystem itself (export/audit) matches all
        code = f.code
        for i, t in enumerate(code):
            if (
                t.kind == "ident"
                and t.text == "Event"
                and i + 3 < len(code)
                and code[i + 1].text == ":"
                and code[i + 2].text == ":"
                and code[i + 3].kind == "ident"
                and not f.is_test_line(t.line)
            ):
                constructed.setdefault(code[i + 3].text, (relpath, t.line))
    for kind in sorted(declared - set(constructed)):
        out.append(
            Violation(
                RULE, trace_rs, 0, f"dead-kind@{kind}",
                f"Event::{kind} is declared in KINDS but never emitted "
                "outside obs/ — dead vocabulary (or its emission site "
                "was dropped in a refactor)",
            )
        )
    for kind in sorted(set(constructed) - declared):
        file, line = constructed[kind]
        out.append(
            Violation(
                RULE, file, line, f"unknown-kind@{kind}",
                f"Event::{kind} is constructed but not in trace.rs KINDS",
            )
        )
    return out

"""Token-level Rust source model for loramlint.

No Rust toolchain exists in this container (ROADMAP "Standing caveat"),
so the lint passes cannot lean on rustc or syn. This module is the
stand-in: a small, exact lexer (comments, raw/byte strings, char vs
lifetime disambiguation, nested block comments) plus structural scans
built on the token stream — brace matching, `#[cfg(test)]` / `#[test]`
item spans, `fn` item extraction with the enclosing `impl`/`mod` path,
and `// lint: allow(rule, "reason")` annotation parsing.

It is a *model*, not a parser: good enough to answer "is this `.unwrap()`
in non-test code?", "which impl does this fn belong to?", "is a borrow
guard still live at this call?" — the questions the passes ask — while
staying a few hundred lines of stdlib Python.
"""

import bisect
import re

KEYWORDS = frozenset(
    (
        "as break const continue crate dyn else enum extern false fn for if "
        "impl in let loop match mod move mut pub ref return self Self static "
        "struct super trait true type unsafe use where while async await"
    ).split()
)

# identifier-ish tokens that precede `[` without forming an index expression
_NON_INDEX_PREV_IDENTS = KEYWORDS


class Tok:
    __slots__ = ("kind", "text", "line")

    def __init__(self, kind, text, line):
        self.kind = kind  # ident | num | str | char | lifetime | punct | comment
        self.text = text
        self.line = line

    def __repr__(self):
        return f"Tok({self.kind},{self.text!r},L{self.line})"


def lex(src):
    """Lex Rust source into a token list (comments included, kind='comment').

    Handles: // and nested /* */ comments, "..." strings with escapes,
    r"..."/r#"..."# raw strings, b"..."/br"..." byte strings, char
    literals vs lifetimes, numeric literals (enough to not split on `.`
    inside floats), multi-char punctuation left as single chars (the
    passes match token sequences, never compound operators).
    """
    toks = []
    i, n, line = 0, len(src), 1
    while i < n:
        c = src[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c in " \t\r":
            i += 1
            continue
        # comments
        if src.startswith("//", i):
            j = src.find("\n", i)
            j = n if j < 0 else j
            toks.append(Tok("comment", src[i:j], line))
            i = j
            continue
        if src.startswith("/*", i):
            depth, j, start_line = 1, i + 2, line
            while j < n and depth:
                if src.startswith("/*", j):
                    depth += 1
                    j += 2
                elif src.startswith("*/", j):
                    depth -= 1
                    j += 2
                else:
                    if src[j] == "\n":
                        line += 1
                    j += 1
            toks.append(Tok("comment", src[i:j], start_line))
            i = j
            continue
        # raw / byte strings: r"..", r#".."#, b"..", br#".."#
        m = re.match(r'(?:b?r)(#*)"', src[i : i + 8])
        if m and src[i] in "br":
            hashes = m.group(1)
            close = '"' + hashes
            j = src.find(close, i + len(m.group(0)))
            j = n if j < 0 else j + len(close)
            text = src[i:j]
            toks.append(Tok("str", text, line))
            line += text.count("\n")
            i = j
            continue
        if c == '"' or (c == "b" and i + 1 < n and src[i + 1] == '"'):
            j = i + (2 if c == "b" else 1)
            while j < n:
                if src[j] == "\\":
                    j += 2
                    continue
                if src[j] == '"':
                    j += 1
                    break
                j += 1
            text = src[i:j]
            toks.append(Tok("str", text, line))
            line += text.count("\n")
            i = j
            continue
        # char literal vs lifetime
        if c == "'":
            m = re.match(r"'(\\.|[^'\\])'", src[i : i + 8])
            if m:
                toks.append(Tok("char", m.group(0), line))
                i += len(m.group(0))
                continue
            m = re.match(r"'[A-Za-z_][A-Za-z0-9_]*", src[i:])
            if m:
                toks.append(Tok("lifetime", m.group(0), line))
                i += len(m.group(0))
                continue
            toks.append(Tok("punct", c, line))
            i += 1
            continue
        # identifiers / keywords
        if c.isalpha() or c == "_":
            m = re.match(r"[A-Za-z_][A-Za-z0-9_]*", src[i:])
            toks.append(Tok("ident", m.group(0), line))
            i += len(m.group(0))
            continue
        # numbers (floats keep their dot so `1.0` is not an index recv)
        if c.isdigit():
            m = re.match(r"\d[\d_]*(?:\.\d[\d_]*)?(?:[eE][+-]?\d+)?\w*", src[i:])
            toks.append(Tok("num", m.group(0), line))
            i += len(m.group(0))
            continue
        toks.append(Tok("punct", c, line))
        i += 1
    return toks


_ALLOW_RE = re.compile(
    r"lint:\s*allow\(\s*([a-z_-]+)\s*(?:,\s*\"([^\"]*)\")?\s*\)"
)

# short rule aliases accepted in annotations -> pass names
RULE_ALIASES = {
    "panic": "panic-surface",
    "panic-surface": "panic-surface",
    "result": "result-hygiene",
    "result-hygiene": "result-hygiene",
    "lock": "lock-discipline",
    "lock-discipline": "lock-discipline",
    "trace": "trace-coverage",
    "trace-coverage": "trace-coverage",
    "contract": "contract-mirror",
    "contract-mirror": "contract-mirror",
}


class Fn:
    __slots__ = ("name", "qual", "start_line", "end_line", "body", "is_test")

    def __init__(self, name, qual, start_line, end_line, body, is_test):
        self.name = name
        self.qual = qual  # "Impl::name" or "name"
        self.start_line = start_line
        self.end_line = end_line
        self.body = body  # list of code Toks (between the body braces)
        self.is_test = is_test

    def __repr__(self):
        return f"Fn({self.qual} L{self.start_line}-{self.end_line})"


class RustFile:
    """One parsed Rust source file: tokens, test spans, fns, annotations."""

    def __init__(self, path, src):
        self.path = path
        self.src = src
        self.lines = src.splitlines()
        toks = lex(src)
        self.comments = [t for t in toks if t.kind == "comment"]
        self.code = [t for t in toks if t.kind != "comment"]
        self._test_spans = _test_spans(self.code)
        self._comment_only_lines = _comment_only_lines(self.comments, self.code)
        self._allows = self._parse_allows()
        self.fns = _extract_fns(self.code, self.is_test_line)

    @classmethod
    def from_path(cls, path):
        with open(path, encoding="utf-8") as f:
            return cls(path, f.read())

    # -- test regions -----------------------------------------------------
    def is_test_line(self, line):
        i = bisect.bisect_right(self._test_spans, (line, float("inf"))) - 1
        if i < 0:
            return False
        lo, hi = self._test_spans[i]
        return lo <= line <= hi

    # -- annotations ------------------------------------------------------
    def _parse_allows(self):
        """line -> [(rule, reason)]. A trailing comment covers its own
        line; a standalone annotation comment covers the next line."""
        allows = {}
        for t in self.comments:
            for rule, reason in _ALLOW_RE.findall(t.text):
                target = t.line
                if t.line in self._comment_only_lines:
                    target = t.line + 1
                allows.setdefault(target, []).append(
                    (RULE_ALIASES.get(rule, rule), reason or "")
                )
        return allows

    def allow(self, line, rule):
        """Return the (rule, reason) annotation covering `line`, or None.
        An allow with an empty reason does NOT count (reasons are part of
        the contract) — callers surface that as its own violation via
        `bare_allow`."""
        for r, reason in self._allows.get(line, []):
            if r == rule and reason.strip():
                return (r, reason)
        return None

    def bare_allow(self, line, rule):
        """True when `line` carries an allow for `rule` with no reason."""
        return any(
            r == rule and not reason.strip()
            for r, reason in self._allows.get(line, [])
        )

    def line_text(self, line):
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""


def norm_line(text):
    """Whitespace-collapsed fingerprint of a source line (baseline key)."""
    return re.sub(r"\s+", " ", text.strip())[:160]


def _comment_only_lines(comments, code):
    code_lines = {t.line for t in code}
    return {t.line for t in comments if t.line not in code_lines}


def _attr_span(code, i):
    """code[i] is '#': return (attr_text, next_index) past the `#[...]`
    (or `#![...]`) group, else None."""
    j = i + 1
    if j < len(code) and code[j].kind == "punct" and code[j].text == "!":
        j += 1
    if j >= len(code) or code[j].text != "[":
        return None
    depth, k, parts = 0, j, []
    while k < len(code):
        t = code[k]
        if t.text == "[":
            depth += 1
        elif t.text == "]":
            depth -= 1
            if depth == 0:
                return ("".join(parts[1:]), k + 1)
        parts.append(t.text)
        k += 1
    return ("".join(parts[1:]), len(code))


def _is_test_attr(attr):
    return (
        "cfg(test" in attr
        or "cfg(any(test" in attr
        or attr == "test"
        or attr.endswith("::test")
    )


def _item_end(code, i):
    """From index i (start of an item after its attributes), return the
    index just past the item: past the matching `}` of its first
    top-level `{`, or past the first `;` before any `{`."""
    depth = 0
    k = i
    while k < len(code):
        t = code[k]
        if t.text == ";" and depth == 0:
            return k + 1
        if t.text in "({[":
            depth += 1
        elif t.text in ")}]":
            depth -= 1
            if depth == 0 and t.text == "}":
                return k + 1
        k += 1
    return len(code)


def _test_spans(code):
    """Merged, sorted (start_line, end_line) spans of #[cfg(test)]/#[test]
    items."""
    spans = []
    i = 0
    while i < len(code):
        t = code[i]
        if t.kind == "punct" and t.text == "#":
            got = _attr_span(code, i)
            if got:
                attr, nxt = got
                if _is_test_attr(attr):
                    # skip any further stacked attributes
                    k = nxt
                    while k < len(code) and code[k].text == "#":
                        more = _attr_span(code, k)
                        if not more:
                            break
                        k = more[1]
                    end = _item_end(code, k)
                    if k < len(code):
                        last = code[min(end, len(code)) - 1]
                        spans.append((t.line, last.line))
                i = nxt
                continue
        i += 1
    spans.sort()
    merged = []
    for lo, hi in spans:
        if merged and lo <= merged[-1][1]:
            merged[-1] = (merged[-1][0], max(hi, merged[-1][1]))
        else:
            merged.append((lo, hi))
    return merged


def _impl_name(code, i):
    """code[i] is the 'impl' ident: return the Self-type name the block
    implements ('Server' for `impl<E: X> Trait for Server<E> where ...`)."""
    parts = []
    depth = 0
    k = i + 1
    while k < len(code):
        t = code[k]
        if t.text == "<":
            depth += 1
        elif t.text == ">":
            depth = max(0, depth - 1)
        elif depth == 0:
            if t.text == "{" or (t.kind == "ident" and t.text == "where"):
                break
            parts.append(t)
        k += 1
    # `impl Trait for Type` -> the Type side
    for j, t in enumerate(parts):
        if t.kind == "ident" and t.text == "for":
            parts = parts[j + 1 :]
            break
    for t in parts:
        if t.kind == "ident" and t.text not in ("dyn", "mut", "const"):
            return t.text
    return "?"


def _extract_fns(code, is_test_line):
    """All `fn` items with qualified names and body token slices.

    Walks the token stream with a context stack of `impl`/`mod` blocks
    (matched by brace depth) so each fn knows its enclosing type.
    Trait-method *declarations* (`fn f(...);`) have no body and are
    skipped."""
    fns = []
    stack = []  # (kind, name, close_depth)
    depth = 0
    i = 0
    n = len(code)
    while i < n:
        t = code[i]
        if t.text in "({[":
            depth += 1
            i += 1
            continue
        if t.text in ")}]":
            depth -= 1
            while stack and depth < stack[-1][2]:
                stack.pop()
            i += 1
            continue
        if t.kind == "ident" and t.text in ("impl", "mod", "trait"):
            name = _impl_name(code, i) if t.text == "impl" else (
                code[i + 1].text if i + 1 < n and code[i + 1].kind == "ident" else "?"
            )
            # find the block open (mod decls `mod x;` have none)
            k = i + 1
            d = 0
            while k < n:
                tk = code[k]
                if tk.text == ";" and d == 0:
                    k = None
                    break
                if tk.text == "<":
                    d += 1
                elif tk.text == ">":
                    d = max(0, d - 1)
                elif tk.text == "{" and d == 0:
                    break
                k += 1
            if k is not None and k < n:
                stack.append((t.text, name, depth + 1))
                depth += 1
                i = k + 1
                continue
            i += 1
            continue
        if t.kind == "ident" and t.text == "fn":
            if i + 1 < n and code[i + 1].kind == "ident":
                name = code[i + 1].text
                # scan to the body `{` (skip generics/args/ret/where) or a
                # `;` (trait declaration, no body)
                k = i + 2
                d = 0
                body_open = None
                while k < n:
                    tk = code[k]
                    if tk.text == ";" and d == 0:
                        break
                    if tk.text in "(<[":
                        d += 1
                    elif tk.text in ")>]":
                        d = max(0, d - 1)
                    elif tk.text == "{" and d == 0:
                        body_open = k
                        break
                    k += 1
                if body_open is not None:
                    # matching close of the body
                    d2 = 0
                    j = body_open
                    while j < n:
                        if code[j].text in "({[":
                            d2 += 1
                        elif code[j].text in ")}]":
                            d2 -= 1
                            if d2 == 0:
                                break
                        j += 1
                    qual = name
                    for kind, sname, _ in reversed(stack):
                        if kind in ("impl", "trait"):
                            qual = f"{sname}::{name}"
                            break
                    fns.append(
                        Fn(
                            name,
                            qual,
                            t.line,
                            code[min(j, n - 1)].line,
                            code[body_open + 1 : j],
                            is_test_line(t.line),
                        )
                    )
                    # continue scanning *inside* the body too (nested fns,
                    # and the context stack needs the braces): do not skip
            i += 1
            continue
        i += 1
    return fns


def find_index_sites(code, *, is_test_line, skip_lines=()):
    """Yield (line, prev_text) for every index expression `recv[...]` in
    non-test code: a `[` whose previous token is an identifier (not a
    keyword), `)`, `]`, or `?` — array literals/types (`[0; 4]`,
    `: [f32; 4]`, `&[..]`, `vec![`) never match because their `[` follows
    punctuation or a macro `!`."""
    for i, t in enumerate(code):
        if t.text != "[" or t.kind != "punct" or i == 0:
            continue
        p = code[i - 1]
        if is_test_line(t.line) or t.line in skip_lines:
            continue
        if p.kind == "ident" and p.text not in _NON_INDEX_PREV_IDENTS:
            yield (t.line, p.text)
        elif p.kind == "punct" and p.text in (")", "]", "?"):
            yield (t.line, p.text)

"""loramlint: stdlib-only static analysis for the loram Rust stack.

Five passes over a token-level Rust source model (`rustsrc.py`):

  panic-surface    no unwrap/expect/panic!/slice-index in hot paths
  contract-mirror  Rust<->Python shared constants/formulas stay in sync
  trace-coverage   state transitions keep their Event emission sites
  lock-discipline  no guard held across engine calls; lock-order table
  result-hygiene   no `let _ =` discards in coordinator/

Violations ratchet against the committed `baseline.json` (monotone
shrink). See DESIGN.md §2h; entry point: `python3 tools/loramlint rust/src`.
"""

__version__ = "1.0"

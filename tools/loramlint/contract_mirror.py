"""contract-mirror: declarative cross-language invariant pairs.

The Rust serving stack and the Python emitter/auditors cannot share
code, so every shared constant or formula lives twice. Each CONTRACT
below names the two source-of-truth sites and how to extract a
comparable value from each *source text*; drift fails the lint with the
exact diff. This generalizes the old `tools/event_sync_check.py` (which
survives as a thin shim over the `event-kinds` contract here).

Shipped pairs:

  chunk-ladder          kvcache.rs::chunk_ladder     ~ aot.py::chunk_ladder
                        (the bucket constants: the probe-by-formula
                        artifact discovery contract, DESIGN.md §2e)
  paged-geometry        kvcache.rs::{PAGED_BLOCK, paged_pool_blocks}
                        ~ aot.py::{PAGED_BLOCK, paged_pool_blocks}
                        (pool bytes == dense grid bytes, §2f)
  trace-schema-version  export.rs::TRACE_SCHEMA_VERSION
                        ~ trace_report.py::TRACE_SCHEMA_VERSION
  event-kinds           trace.rs::Event enum == trace.rs::KINDS const
                        == trace_report.py::KINDS (names, order, fields)
  metrics-keys          every registry key bench_main.rs / tab8.rs /
                        trace_report.py *consumes* must be *produced* by
                        ServerStats::to_metrics (+ the stats structs'
                        export_into) / main.rs's serverStats embedding
  workload-scenarios    workload.rs::SCENARIOS ~ workload_gen.py::
                        SCENARIOS (names and order: the adversarial
                        workload catalog, DESIGN.md §2i — slo_sim.py and
                        the CLI both resolve scenario names through it)
  chaos-scenarios       chaos.rs::CHAOS_SCENARIOS ~ chaos_gen.py::
                        CHAOS_SCENARIOS (the fault-plan catalog,
                        DESIGN.md §2j — both sides pregenerate the same
                        schedule draw-for-draw)
  fault-kinds           chaos.rs::FAULT_KINDS ~ chaos_gen.py::
                        FAULT_KINDS (names AND order: a plan's `kind_ix`
                        indexes this table on both sides, so reordering
                        silently re-aims every scheduled fault)

To add a pair: write an extractor for each side returning a comparable
value, append a Contract to CONTRACTS, and add a drift + clean fixture
to python/tests/test_loramlint.py (DESIGN.md §2h walks through one).
"""

import re

from .report import Violation

RULE = "contract-mirror"


# -- generic source extraction helpers ---------------------------------------

def _strip_py_strings(text):
    text = re.sub(r'("""|\'\'\')(?:.|\n)*?\1', "", text)
    return re.sub(r'#[^\n]*', "", text)


def py_def_body(src, name):
    """Body text of `def name(...):` up to the next top-level statement,
    docstrings/comments stripped. None when the def is missing."""
    m = re.search(rf"^def {re.escape(name)}\(.*?\):", src, re.M | re.S)
    if not m:
        return None
    rest = src[m.end():]
    stop = re.search(r"^\S", rest, re.M)
    body = rest[: stop.start()] if stop else rest
    return _strip_py_strings(body)


def rust_fn_ints(rf, name):
    """Sorted unique integer literals in the body of free fn `name`."""
    for fn in rf.fns:
        if fn.qual == name and not fn.is_test:
            return sorted(
                {
                    int(t.text.replace("_", ""))
                    for t in fn.body
                    if t.kind == "num" and t.text.replace("_", "").isdigit()
                }
            )
    return None


def py_body_ints(body):
    return sorted({int(x) for x in re.findall(r"\b\d+\b", body)})


def rust_const_int(src, name):
    m = re.search(
        rf"\bconst {re.escape(name)}\s*:\s*\w+\s*=\s*(\d+)", src
    )
    return int(m.group(1)) if m else None


def py_const_int(src, name):
    m = re.search(rf"^{re.escape(name)}\s*=\s*(\d+)\s*$", src, re.M)
    return int(m.group(1)) if m else None


def _norm_formula(text):
    """Whitespace-free, `//`->`/` normal form of an arithmetic expr."""
    return re.sub(r"\s+", "", text).replace("//", "/")


def rust_fn_return_expr(rf, name):
    """The body text of a one-expression free fn, normalized."""
    for fn in rf.fns:
        if fn.qual == name and not fn.is_test:
            return _norm_formula("".join(t.text for t in fn.body))
    return None


def py_return_expr(body):
    m = re.search(r"return\s+(.+)", body)
    return _norm_formula(m.group(1)) if m else None


# -- event-kinds (the old event_sync_check, now a contract) ------------------

def parse_rust_event_enum(src, path="trace.rs"):
    """[(variant, [fields...])] from `pub enum Event { ... }` (one variant
    per line, struct-style fields)."""
    m = re.search(r"pub enum Event \{(.*?)\n\}", src, re.S)
    if not m:
        raise _Extract(f"{path}: could not find `pub enum Event {{ ... }}`")
    variants = []
    for line in m.group(1).splitlines():
        vm = re.match(r"([A-Z]\w*)\s*\{([^}]*)\}", line.strip())
        if not vm:
            continue  # doc comments, attributes, blank lines
        fields = re.findall(r"(\w+)\s*:", vm.group(2))
        variants.append((vm.group(1), fields))
    if not variants:
        raise _Extract(
            f"{path}: parsed zero Event variants — is the enum still "
            "one-variant-per-line?"
        )
    return variants


def parse_rust_kinds_const(src, path="trace.rs"):
    m = re.search(r"pub const KINDS[^=]*=\s*&\[(.*?)\];", src, re.S)
    if not m:
        raise _Extract(f"{path}: could not find `pub const KINDS`")
    return re.findall(r'"(\w+)"', m.group(1))


def parse_python_kinds(src, path="trace_report.py"):
    m = re.search(r"^KINDS = \{(.*?)\n\}", src, re.S | re.M)
    if not m:
        raise _Extract(f"{path}: could not find `KINDS = {{ ... }}`")
    kinds = []
    for line in m.group(1).splitlines():
        km = re.match(r'\s*"(\w+)":\s*\(([^)]*)\)', line)
        if km:
            kinds.append((km.group(1), re.findall(r'"(\w+)"', km.group(2))))
    if not kinds:
        raise _Extract(f"{path}: parsed zero kinds from KINDS")
    return kinds


def diff_event_kinds(rust_variants, rust_const, py_kinds):
    """The event_sync_check comparison, returned as problem strings."""
    errs = []
    rust_names = [n for n, _ in rust_variants]
    py_names = [n for n, _ in py_kinds]
    if rust_names != rust_const:
        errs.append(
            "trace.rs: `Event` variants and the `KINDS` const disagree:\n"
            f"  enum : {rust_names}\n  const: {rust_const}"
        )
    if rust_names != py_names:
        only_rust = [n for n in rust_names if n not in py_names]
        only_py = [n for n in py_names if n not in rust_names]
        detail = []
        if only_rust:
            detail.append(f"only in trace.rs: {only_rust}")
        if only_py:
            detail.append(f"only in trace_report.py: {only_py}")
        if not detail:
            detail.append(
                f"order differs:\n  rust:   {rust_names}\n  python: {py_names}"
            )
        errs.append("event kinds drifted — " + "; ".join(detail))
    else:
        for (name, rf_), (_, pf) in zip(rust_variants, py_kinds):
            if rf_ != pf:
                errs.append(
                    f"{name}: payload fields drifted — trace.rs has {rf_}, "
                    f"trace_report.py has {pf}"
                )
    return errs


# -- workload-scenarios ------------------------------------------------------

def parse_rust_scenarios(src, path="workload.rs"):
    m = re.search(r"pub const SCENARIOS[^=]*=\s*&\[(.*?)\];", src, re.S)
    if not m:
        raise _Extract(f"{path}: could not find `pub const SCENARIOS`")
    names = re.findall(r'"([\w-]+)"', m.group(1))
    if not names:
        raise _Extract(f"{path}: parsed zero scenario names from SCENARIOS")
    return names


def parse_python_scenarios(src, path="workload_gen.py"):
    m = re.search(r"^SCENARIOS = \[(.*?)\]", src, re.S | re.M)
    if not m:
        raise _Extract(f"{path}: could not find `SCENARIOS = [ ... ]`")
    names = re.findall(r'"([\w-]+)"', m.group(1))
    if not names:
        raise _Extract(f"{path}: parsed zero scenario names from SCENARIOS")
    return names


# -- chaos-scenarios / fault-kinds -------------------------------------------

def parse_rust_const_list(src, name, path):
    """String items of `pub const NAME: &[&str] = &[ ... ];`."""
    m = re.search(
        rf"pub const {re.escape(name)}[^=]*=\s*&\[(.*?)\];", src, re.S
    )
    if not m:
        raise _Extract(f"{path}: could not find `pub const {name}`")
    names = re.findall(r'"([\w-]+)"', m.group(1))
    if not names:
        raise _Extract(f"{path}: parsed zero names from {name}")
    return names


def parse_python_const_list(src, name, path):
    """String items of a module-level `NAME = [ ... ]` list."""
    m = re.search(rf"^{re.escape(name)} = \[(.*?)\]", src, re.S | re.M)
    if not m:
        raise _Extract(f"{path}: could not find `{name} = [ ... ]`")
    names = re.findall(r'"([\w-]+)"', m.group(1))
    if not names:
        raise _Extract(f"{path}: parsed zero names from {name}")
    return names


# -- metrics-keys ------------------------------------------------------------

PRODUCER_FILES = (
    "rust/src/serve.rs",
    "rust/src/coordinator/kvcache.rs",
    "rust/src/coordinator/speculative.rs",
)
CONSUMER_RS = (
    "rust/benches/bench_main.rs",
    "rust/src/coordinator/experiments/tab8.rs",
)
NAMESPACES = ("serve.", "prefill.", "spec.", "paged.")

_PRODUCE_RE = re.compile(
    r'\b(?:set_counter|set_gauge|inc|observe|observe_all)\(\s*"([^"]+)"'
)
_CONSUME_RE = re.compile(
    r'\b(?:counter|gauge|has_counter|has_gauge|hist|hist_pcts|c|g)\(\s*"([^"]+)"'
)
_ADAPTER_FIELD_RE = re.compile(r'\bk\(\s*"([^"]+)"\s*\)')
_STATS_GET_RE = re.compile(r'stats\.get\(\s*f?"([^"{}]+)"')
_SERVERSTATS_KEY_RE = re.compile(r'\(\s*"([a-z_0-9]+)"\s*,\s*Json::num')


def check_metrics_keys(read):
    """`read(relpath) -> text or None`; returns problem strings."""
    errs = []
    produced, prod_adapter = set(), set()
    for relpath in PRODUCER_FILES:
        text = read(relpath)
        if text is None:
            errs.append(f"metrics producer missing: {relpath}")
            continue
        produced.update(_PRODUCE_RE.findall(text))
        prod_adapter.update(_ADAPTER_FIELD_RE.findall(text))
    consumed, cons_adapter = {}, {}
    for relpath in CONSUMER_RS:
        text = read(relpath)
        if text is None:
            errs.append(f"metrics consumer missing: {relpath}")
            continue
        for key in _CONSUME_RE.findall(text):
            if key.startswith(NAMESPACES):
                consumed.setdefault(key, relpath)
        for f in _ADAPTER_FIELD_RE.findall(text):
            cons_adapter.setdefault(f, relpath)
    for key in sorted(consumed):
        if key not in produced:
            errs.append(
                f"{consumed[key]} reads registry key '{key}' but no "
                "producer exports it (ServerStats::to_metrics / "
                "export_into renamed or dropped it?)"
            )
    for f in sorted(cons_adapter):
        if f not in prod_adapter:
            errs.append(
                f"{cons_adapter[f]} reads per-adapter field '{f}' but "
                "ServerStats::to_metrics does not export it"
            )
    # serverStats side-channel: trace_report.py's --check keys must be
    # embedded by main.rs's trace_finish
    report = read("tools/trace_report.py")
    mainrs = read("rust/src/main.rs")
    if report is None or mainrs is None:
        errs.append("trace_report.py or main.rs missing for serverStats check")
        return errs
    embedded = set(_SERVERSTATS_KEY_RE.findall(mainrs))
    for key in sorted(set(_STATS_GET_RE.findall(report))):
        expanded = [key]
        if "{" in key or "}" in key:
            continue  # f-string key, handled below
        for k in expanded:
            if k not in embedded:
                errs.append(
                    f"trace_report.py --check reads serverStats['{k}'] but "
                    "main.rs trace_finish does not embed it"
                )
    # the f-string percentile keys: f"{key}_tick_p{p}" over ttft/itl, 50/95
    if re.search(r'stats\.get\(f"\{key\}_tick_p\{p\}"\)', report):
        for k in ("ttft", "itl"):
            for p in (50, 95):
                want = f"{k}_tick_p{p}"
                if want not in embedded:
                    errs.append(
                        f"trace_report.py --check reads serverStats"
                        f"['{want}'] but main.rs trace_finish does not "
                        "embed it"
                    )
    return errs


# -- the contract table ------------------------------------------------------

class _Extract(Exception):
    """Extraction failed: the mirror's anchor text is gone."""


class Contract:
    def __init__(self, name, check):
        self.name = name
        self.check = check  # fn(ctx) -> [problem strings]


def _chunk_ladder(ctx):
    rf = ctx.rust_file("rust/src/coordinator/kvcache.rs")
    aot = ctx.read("python/compile/aot.py")
    if rf is None or aot is None:
        return ["kvcache.rs or aot.py missing"]
    rust = rust_fn_ints(rf, "chunk_ladder")
    body = py_def_body(aot, "chunk_ladder")
    if rust is None:
        return ["kvcache.rs: free fn `chunk_ladder` not found"]
    if body is None:
        return ["aot.py: `def chunk_ladder` not found"]
    py = py_body_ints(body)
    if rust != py:
        return [
            f"chunk_ladder bucket constants drifted — kvcache.rs uses "
            f"{rust}, aot.py uses {py}"
        ]
    return []


def _paged_geometry(ctx):
    rf = ctx.rust_file("rust/src/coordinator/kvcache.rs")
    aot = ctx.read("python/compile/aot.py")
    if rf is None or aot is None:
        return ["kvcache.rs or aot.py missing"]
    errs = []
    r_block = rust_const_int(rf.src, "PAGED_BLOCK")
    p_block = py_const_int(aot, "PAGED_BLOCK")
    if r_block is None:
        errs.append("kvcache.rs: `pub const PAGED_BLOCK` not found")
    if p_block is None:
        errs.append("aot.py: `PAGED_BLOCK = <int>` not found")
    if None not in (r_block, p_block) and r_block != p_block:
        errs.append(
            f"PAGED_BLOCK drifted — kvcache.rs says {r_block}, aot.py "
            f"says {p_block}"
        )
    r_formula = rust_fn_return_expr(rf, "paged_pool_blocks")
    body = py_def_body(aot, "paged_pool_blocks")
    p_formula = py_return_expr(body) if body else None
    if r_formula is None:
        errs.append("kvcache.rs: free fn `paged_pool_blocks` not found")
    if p_formula is None:
        errs.append("aot.py: `def paged_pool_blocks` return expr not found")
    if None not in (r_formula, p_formula) and r_formula != p_formula:
        errs.append(
            f"paged_pool_blocks formula drifted — kvcache.rs computes "
            f"`{r_formula}`, aot.py computes `{p_formula}`"
        )
    return errs


def _trace_schema_version(ctx):
    export = ctx.read("rust/src/obs/export.rs")
    report = ctx.read("tools/trace_report.py")
    if export is None or report is None:
        return ["export.rs or trace_report.py missing"]
    r = rust_const_int(export, "TRACE_SCHEMA_VERSION")
    p = py_const_int(report, "TRACE_SCHEMA_VERSION")
    if r is None:
        return ["export.rs: `TRACE_SCHEMA_VERSION` const not found"]
    if p is None:
        return [
            "trace_report.py: `TRACE_SCHEMA_VERSION = <int>` not found — "
            "the auditor must pin the schema it understands"
        ]
    if r != p:
        return [
            f"TRACE_SCHEMA_VERSION drifted — export.rs writes {r}, "
            f"trace_report.py expects {p}"
        ]
    return []


def _event_kinds(ctx):
    trace = ctx.read("rust/src/obs/trace.rs")
    report = ctx.read("tools/trace_report.py")
    if trace is None or report is None:
        return ["trace.rs or trace_report.py missing"]
    try:
        variants = parse_rust_event_enum(trace)
        const = parse_rust_kinds_const(trace)
        py = parse_python_kinds(report)
    except _Extract as e:
        return [str(e)]
    return diff_event_kinds(variants, const, py)


def _metrics_keys(ctx):
    return check_metrics_keys(ctx.read)


def _workload_scenarios(ctx):
    workload = ctx.read("rust/src/workload.rs")
    gen = ctx.read("tools/workload_gen.py")
    if workload is None or gen is None:
        return ["workload.rs or workload_gen.py missing"]
    try:
        rust = parse_rust_scenarios(workload)
        py = parse_python_scenarios(gen)
    except _Extract as e:
        return [str(e)]
    if rust != py:
        return [
            f"workload scenario catalog drifted — workload.rs has {rust}, "
            f"workload_gen.py has {py} (names and order are the contract; "
            "the generators must mirror draw-for-draw)"
        ]
    return []


def _chaos_scenarios(ctx):
    chaos = ctx.read("rust/src/chaos.rs")
    gen = ctx.read("tools/chaos_gen.py")
    if chaos is None or gen is None:
        return ["chaos.rs or chaos_gen.py missing"]
    try:
        rust = parse_rust_const_list(chaos, "CHAOS_SCENARIOS", "chaos.rs")
        py = parse_python_const_list(gen, "CHAOS_SCENARIOS", "chaos_gen.py")
    except _Extract as e:
        return [str(e)]
    if rust != py:
        return [
            f"chaos scenario catalog drifted — chaos.rs has {rust}, "
            f"chaos_gen.py has {py} (names and order are the contract; "
            "the plan generators must mirror draw-for-draw)"
        ]
    return []


def _fault_kinds(ctx):
    chaos = ctx.read("rust/src/chaos.rs")
    gen = ctx.read("tools/chaos_gen.py")
    if chaos is None or gen is None:
        return ["chaos.rs or chaos_gen.py missing"]
    try:
        rust = parse_rust_const_list(chaos, "FAULT_KINDS", "chaos.rs")
        py = parse_python_const_list(gen, "FAULT_KINDS", "chaos_gen.py")
    except _Extract as e:
        return [str(e)]
    if rust != py:
        return [
            f"fault taxonomy drifted — chaos.rs has {rust}, chaos_gen.py "
            f"has {py} (a plan's kind_ix indexes this table on both "
            "sides: names AND order are the contract)"
        ]
    return []


CONTRACTS = (
    Contract("chunk-ladder", _chunk_ladder),
    Contract("paged-geometry", _paged_geometry),
    Contract("trace-schema-version", _trace_schema_version),
    Contract("event-kinds", _event_kinds),
    Contract("metrics-keys", _metrics_keys),
    Contract("workload-scenarios", _workload_scenarios),
    Contract("chaos-scenarios", _chaos_scenarios),
    Contract("fault-kinds", _fault_kinds),
)


def run(ctx):
    out = []
    for c in ctx.config.get("contracts", CONTRACTS):
        for problem in c.check(ctx):
            out.append(
                Violation(
                    RULE, "contract", 0, f"{c.name}@{problem[:120]}",
                    f"[{c.name}] {problem}",
                )
            )
    return out

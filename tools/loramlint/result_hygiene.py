"""result-hygiene: no `let _ =` discards in coordinator/.

A `let _ = fallible_call()` in the coordinator silently swallows the
`Err` — the serving stack's cleanup paths (block release, row eviction)
must either propagate, log, or carry a
`// lint: allow(result, "reason")` saying why the discard is sound
(e.g. the value is an `Option` drained on purpose). Scope is
`coordinator/` (plus any configured extra files): that's where Result
values gate resource lifecycles.

The lint is type-blind (no compiler here), so it flags the *pattern* —
`let _ =` with a wildcard binding — rather than proving the RHS is a
`Result`. Named discards (`let _released = ...`) are visible in review
and not flagged.
"""

from .report import Violation
from .rustsrc import norm_line

RULE = "result-hygiene"

SCOPE_PREFIX = "rust/src/coordinator/"


def run(ctx):
    out = []
    for relpath, rf in ctx.rust_files.items():
        if not relpath.startswith(ctx.config.get("result_scope", SCOPE_PREFIX)):
            continue
        code = rf.code
        for i, t in enumerate(code):
            if t.kind != "ident" or t.text != "let":
                continue
            if rf.is_test_line(t.line):
                continue
            nxt = code[i + 1] if i + 1 < len(code) else None
            nxt2 = code[i + 2] if i + 2 < len(code) else None
            if (
                nxt is not None
                and nxt.kind == "ident"
                and nxt.text == "_"
                and nxt2 is not None
                and nxt2.text == "="
            ):
                if rf.allow(t.line, RULE):
                    continue
                key = f"let-discard@{norm_line(rf.line_text(t.line))}"
                msg = "`let _ =` discards a fallible value in coordinator/"
                if rf.bare_allow(t.line, RULE):
                    msg += " (its lint:allow has no reason)"
                out.append(Violation(RULE, relpath, t.line, key, msg))
    return out

"""lock-discipline: no Mutex/RefCell guard held across an engine call.

The async pipelined engine (ROADMAP) will put real locks into the paths
where today a single-threaded `RefCell` guards `DecodeState`. A guard
held across `Session::run` / `donate_slots` is exactly the shape that
deadlocks (or double-borrows) once those calls overlap ticks on another
thread — so this pass:

  1. flags any `.borrow()` / `.borrow_mut()` / `.lock()` /
     `.try_lock()` guard still live at a `.run(` / `donate_slots(` /
     `.take_slot(` / `.put_slot(` call in the same fn (liveness ends at
     `drop(guard)`, at the guard's block close, or — for guards that are
     never `let`-bound — at the end of the statement);
  2. records the lock-acquisition-order table: per fn, the receiver
     paths acquired in order while earlier guards are live, and fails on
     a global order inversion (A-then-B in one fn, B-then-A in another),
     the classic deadlock precondition.

The existing `Generator` borrow-across-run sites are *known debt*,
ratcheted in the committed baseline: the gate exists so the count only
shrinks as the async refactor lands, and no NEW site slips in.
`// lint: allow(lock, "reason")` is the per-site escape hatch.
"""

from .report import Violation
from .rustsrc import norm_line

RULE = "lock-discipline"

TARGETS = (
    "rust/src/serve.rs",
    "rust/src/coordinator/kvcache.rs",
    "rust/src/coordinator/generate.rs",
    "rust/src/coordinator/speculative.rs",
    "rust/src/coordinator/adapters.rs",
    "rust/src/coordinator/evaluate.rs",
    "rust/src/runtime/session.rs",
)

ACQUIRE = ("borrow", "borrow_mut", "lock", "try_lock", "read", "write")
# only these receivers make `read`/`write` an acquisition (plain
# `file.read(...)` IO must not count): a path ending in a lock-ish field
LOCKY_HINTS = ("lock", "mutex", "rwlock", "cell", "state")

CROSS_CALLS = ("run", "donate_slots", "take_slot", "put_slot")


def _recv_path(code, i):
    """Receiver path of the call at code[i] (an ACQUIRE ident): walk the
    `a . b . c` chain backwards, returning 'a.b.c'."""
    parts = []
    k = i - 1  # the '.' before the method
    while k >= 1:
        if code[k].text != ".":
            break
        prev = code[k - 1]
        if prev.kind == "ident":
            parts.append(prev.text)
            k -= 2
        elif prev.text in (")", "]"):
            parts.append("(..)")
            break
        else:
            break
    return ".".join(reversed(parts)) or "?"


def _is_acquire(code, i):
    t = code[i]
    if t.kind != "ident" or t.text not in ACQUIRE:
        return False
    if i == 0 or code[i - 1].text != ".":
        return False
    if i + 1 >= len(code) or code[i + 1].text != "(":
        return False
    if t.text in ("read", "write"):
        recv = _recv_path(code, i).lower()
        return any(h in recv for h in LOCKY_HINTS)
    return True


def _is_cross_call(code, i):
    t = code[i]
    if t.kind != "ident" or t.text not in CROSS_CALLS:
        return False
    if i + 1 >= len(code) or code[i + 1].text != "(":
        return False
    # `.run(` / `.donate_slots(` method calls, or bare `donate_slots(`
    return t.text in ("donate_slots",) or (i > 0 and code[i - 1].text == ".")


class _Guard:
    __slots__ = ("name", "recv", "depth", "line", "let_bound")

    def __init__(self, name, recv, depth, line, let_bound):
        self.name = name
        self.recv = recv
        self.depth = depth
        self.line = line
        self.let_bound = let_bound


def scan_fn(fn):
    """Return (violation_sites, order_edges, acquisitions) for one fn body.

    violation_sites: [(line, guard_recv, call_name, guard_line)]
    order_edges: [(earlier_recv, later_recv, fn_qual, line)] observed
    while the earlier guard was live (the deadlock-order relation).
    acquisitions: every acquired receiver path, in order.
    """
    code = fn.body
    sites = []
    order_edges = []
    acquisitions = []
    guards = []  # live _Guard list, in acquisition order
    depth = 0
    stmt_guards = []  # guards born in the current statement (not let-bound)
    pending_let = None  # name of the binding whose init expr we are in
    i = 0
    n = len(code)
    while i < n:
        t = code[i]
        if t.text in "({[":
            depth += 1
        elif t.text in ")}]":
            depth -= 1
            guards = [g for g in guards if g.depth <= depth]
        elif t.text == ";":
            # statement end: temporaries die; a pending let binds its name
            for g in stmt_guards:
                if pending_let is not None:
                    g.name = pending_let
                    g.let_bound = True
                else:
                    guards = [x for x in guards if x is not g]
            stmt_guards = []
            pending_let = None
        elif t.kind == "ident" and t.text == "let":
            # `let [mut] NAME = ...` — remember the name for guards in
            # the init expression
            k = i + 1
            if k < n and code[k].kind == "ident" and code[k].text == "mut":
                k += 1
            if k < n and code[k].kind == "ident":
                pending_let = code[k].text
        elif t.kind == "ident" and t.text == "drop":
            if i + 2 < n and code[i + 1].text == "(" and code[i + 2].kind == "ident":
                victim = code[i + 2].text
                guards = [g for g in guards if g.name != victim]
        elif _is_acquire(code, i):
            recv = _recv_path(code, i)
            acquisitions.append(recv)
            for g in guards:
                order_edges.append((g.recv, recv, fn.qual, t.line))
            g = _Guard(pending_let or "<tmp>", recv, depth, t.line, False)
            guards.append(g)
            stmt_guards.append(g)
        elif _is_cross_call(code, i):
            for g in guards:
                sites.append((t.line, g.recv, t.text, g.line))
        i += 1
    return sites, order_edges, acquisitions


def run(ctx):
    out = []
    all_edges = []
    table = {}  # fn qual -> [recv in acquisition order]
    for relpath in ctx.config.get("lock_targets", TARGETS):
        rf = ctx.rust_file(relpath)
        if rf is None:
            continue
        for fn in rf.fns:
            if fn.is_test:
                continue
            sites, edges, acqs = scan_fn(fn)
            for a, b, qual, _line in edges:
                all_edges.append((a, b, qual))
            if acqs:
                table[f"{relpath}:{fn.qual}"] = acqs
            for line, recv, call, gline in sites:
                if rf.allow(line, RULE):
                    continue
                key = f"held@{norm_line(rf.line_text(line))}"
                out.append(
                    Violation(
                        RULE,
                        relpath,
                        line,
                        key,
                        f"{fn.qual}: `{recv}` guard (acquired line {gline}) "
                        f"held across `{call}(` — not async-engine safe",
                    )
                )
    # global order-inversion check
    fwd = {}
    for a, b, qual in all_edges:
        fwd.setdefault((a, b), qual)
    for (a, b), qual in sorted(fwd.items()):
        if a == b:
            continue
        if (b, a) in fwd:
            other = fwd[(b, a)]
            if (a, b) < (b, a):  # report each inverted pair once
                out.append(
                    Violation(
                        RULE,
                        "rust/src",
                        0,
                        f"order@{a}<>{b}",
                        f"lock-order inversion: {qual} acquires "
                        f"{a} then {b}, {other} acquires {b} then {a}",
                    )
                )
    ctx.artifacts["lock_order_table"] = table
    return out

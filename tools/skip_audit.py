#!/usr/bin/env python3
"""Test-inventory audit for the skip-clean integration tests.

`rust/tests/integration.rs` tests that need optional artifacts skip with a
standardized stderr line ("skipping: artifact '<name>' unavailable (...)")
instead of failing — which is right for artifact-less checkouts but can
silently hollow CI out: a typo'd artifact name, or a suite that stopped
emitting something, makes the test *always* skip and nobody notices.

This audit closes the hole: fed the `--nocapture` test output on stdin and
the artifacts directory as argv[1], it fails when any test skipped over an
artifact that IS present on disk (both halves: .hlo.txt + .meta.json).
Runtime-level skips ("skipping: no PJRT runtime") stay legitimate — a
missing native xla runtime is an environment property, not an inventory
bug.

It also rejects a *torn* paged decode family (§2f): a
`decode_prefill_paged_<m>` on disk without its `decode_step_paged_<m>`
(or vice versa) means every paged test skips with a perfectly legitimate
looking line forever — the family is all-or-nothing at emission, so a
half-present one is a stale artifacts directory, not a choice.

Usage (see ci.sh):
    cargo test --test integration -- --nocapture 2>&1 \
        | python3 tools/skip_audit.py artifacts
"""

import os
import re
import sys


def audit(log: str, art_dir: str):
    """Return (bad, artifact_skips, runtime_skips): `bad` is the sorted set
    of artifacts a test skipped over although both halves are on disk."""
    skipped = re.findall(r"skipping: artifact '([^']+)' unavailable", log)
    bad = sorted({
        name for name in skipped
        if os.path.exists(os.path.join(art_dir, f"{name}.meta.json"))
        and os.path.exists(os.path.join(art_dir, f"{name}.hlo.txt"))
    })
    runtime_skips = len(re.findall(r"skipping: no PJRT runtime", log))
    return bad, len(skipped), runtime_skips


def torn_paged_families(art_dir: str):
    """Models whose paged decode family is half-emitted: prefill without
    step or step without prefill (both halves of each artifact counted,
    like `audit`). The emitter writes the family atomically, so a torn
    one on disk is a stale/corrupt artifacts directory."""
    def on_disk(name):
        return (os.path.exists(os.path.join(art_dir, f"{name}.meta.json"))
                and os.path.exists(os.path.join(art_dir, f"{name}.hlo.txt")))

    models = set()
    if os.path.isdir(art_dir):
        for f in os.listdir(art_dir):
            m = re.match(r"decode_(?:prefill|step)_paged_(.+)\.meta\.json$", f)
            if m:
                models.add(m.group(1))
    return sorted(
        m for m in models
        if on_disk(f"decode_prefill_paged_{m}") != on_disk(f"decode_step_paged_{m}")
    )


def main():
    art_dir = sys.argv[1] if len(sys.argv) > 1 else "artifacts"
    log = sys.stdin.read()
    bad, n_skips, n_runtime = audit(log, art_dir)
    torn = torn_paged_families(art_dir)
    if bad:
        print("skip_audit: tests skipped although their artifacts are "
              "present on disk (stale suite or typo'd artifact name?):")
        for name in bad:
            print(f"  {name}")
        sys.exit(1)
    if torn:
        print("skip_audit: torn paged decode families (prefill/step "
              "halves disagree — stale artifacts directory?):")
        for name in torn:
            print(f"  {name}")
        sys.exit(1)
    print(f"skip_audit: OK — {n_skips} artifact skips (none with artifacts "
          f"present), {n_runtime} runtime skips")


if __name__ == "__main__":
    main()

#!/usr/bin/env python3
"""Audit + report for `loram serve --trace` files.

Reads a trace written by `serve --trace out.json` (the Chrome trace-event
file, whose `loramEvents` key carries the raw typed events) or the compact
`out.jsonl` sibling, replays the event stream, and checks the scheduler's
conservation laws — the same laws `rust/src/obs/audit.rs` enforces inside
`cargo test`:

  1. per request: enqueue <= admit <= first-token <= finish (tick order)
  2. token conservation: DecodeStep count per request == Finish.tokens
  3. lifecycle: every admitted request finishes or is rejected; no decode
     on an unoccupied row; no double-admit of a live row
  4. block discipline: no alloc of a live block, no free of a dead one;
     end-of-trace residency is compared against the exported blocks_in_use
  5. copy-on-write: cow_copies must be 0 under serve (the share-only-
     full-blocks invariant, DESIGN.md Sec 2f)
  6. preemption conservation (Sec 2i): Preempt.tokens equals the
     DecodeStep count of the life it ends; the preempted row is freed;
     total DecodeSteps == sum(Finish.tokens) + preempted_tokens
  7. cancel is terminal and pre-admission: cancelling an in-flight or
     finished request, or any Admit after Cancel, is a violation
  8. admission ledger: admits == finishes + preempts + mid-flight
     rejects + fails, and DeadlineMiss only fires for requests that
     finish
  9. retry ledger (Sec 2j): every Fault is answered by exactly one Retry
     or terminal Failed — per request, faults == retries while live,
     and faults == retries + 1 at an in-flight Failed; Retry attempts
     count 1, 2, ... in order
 10. failure terminality: Failed is a terminal outcome — no event may
     name the request afterwards; Failed.tokens conserves the discarded
     life (like Preempt) into failed_tokens
 11. degradation bracketing: every Degrade("degraded") is closed by a
     Recover or escalates to Degrade("failing"); a trace may only end
     degraded if it ends in the failing state

It then recomputes the TTFT/ITL tick percentiles from the raw events with
the *identical* interpolation the Rust side uses (rank = (p/100)*(n-1),
lerp between the straddling samples — `util::stats::percentile_sorted`),
so under `--check` the recomputed values must equal the `serverStats`
block embedded in the trace file bit-for-bit, not merely approximately.

Usage:
    python3 tools/trace_report.py out.json           # human summary
    python3 tools/trace_report.py --check out.json   # CI gate (exit != 0
                                                     # on any violation)

`KINDS` below mirrors `Event` in rust/src/obs/trace.rs, in enum order —
tools/event_sync_check.py fails CI when the two drift. Keep one kind per
line.
"""

import json
import math
import sys

# The trace schema this auditor understands — must equal
# rust/src/obs/export.rs::TRACE_SCHEMA_VERSION (loramlint contract-mirror
# pass, `trace-schema-version` pair).
TRACE_SCHEMA_VERSION = 1

# kind -> required payload fields, in Rust enum order (one per line).
KINDS = {
    "Enqueue": ("req",),
    "Admit": ("req", "row"),
    "Reject": ("req",),
    "Requeue": ("req",),
    "PrefillWindow": ("row", "start", "bucket"),
    "DecodeStep": ("row",),
    "VerifyRound": ("row", "k", "accepted"),
    "Rewind": ("row", "n"),
    "Evict": ("row",),
    "Finish": ("req", "row", "tokens"),
    "Preempt": ("req", "row", "tokens"),
    "Cancel": ("req",),
    "DeadlineMiss": ("req",),
    "BlockAlloc": ("block",),
    "BlockFree": ("block",),
    "PrefixHit": ("blocks", "tokens"),
    "CowCopy": ("block",),
    "Gauge": ("name", "value"),
    "SessionRun": ("artifact", "h2d_ms", "exec_ms", "d2h_ms"),
    "Fault": ("req", "row", "fault"),
    "Retry": ("req", "attempt"),
    "Failed": ("req", "tokens", "attempts"),
    "Degrade": ("level",),
    "Recover": (),
}


def percentile(xs, p):
    """Bit-identical mirror of util::stats::percentile/percentile_sorted:
    sort, rank = (p/100)*(n-1), lerp between the straddling samples."""
    if not xs:
        return 0.0
    v = sorted(xs)
    rank = (p / 100.0) * (len(v) - 1)
    lo = math.floor(rank)
    hi = math.ceil(rank)
    if lo == hi:
        return float(v[lo])
    return v[lo] + (rank - lo) * (v[hi] - v[lo])


def load(path):
    """Return (events, server_stats_or_None, other_data) from a Chrome
    trace file (loramEvents key) or a .jsonl event log."""
    with open(path) as f:
        text = f.read()
    try:
        doc = json.loads(text)
    except json.JSONDecodeError:
        # one event object per line: the .jsonl sibling
        events = [json.loads(line) for line in text.splitlines() if line.strip()]
        return events, None, {}
    if isinstance(doc, dict) and "kind" in doc:
        return [doc], None, {}  # single-line .jsonl parses as one object
    if "loramEvents" not in doc:
        raise SystemExit(
            f"{path}: JSON object without 'loramEvents' — not a "
            "`serve --trace` file"
        )
    return doc["loramEvents"], doc.get("serverStats"), doc.get("otherData", {})


def audit(events):
    """Replay the event stream; mirror of rust/src/obs/audit.rs::audit."""
    r = {
        "violations": [],
        "ttft_ticks": [],
        "itl_ticks": [],
        "enqueued": 0,
        "admitted": 0,
        "finished": 0,
        "rejected": 0,
        "requeues": 0,
        "tokens": 0,
        "preempted": 0,
        "preempted_tokens": 0,
        "cancelled": 0,
        "deadline_misses": 0,
        "faults": 0,
        "retries": 0,
        "failed": 0,
        "failed_tokens": 0,
        "degrades": 0,
        "cow_copies": 0,
        "prefix_hits": 0,
        "verify_rounds": 0,
        "session_runs": 0,
        "gauges": {},
    }
    bad = r["violations"].append
    lives = {}  # req -> life dict
    rows = {}  # engine row -> occupant req
    live_blocks = {}  # block -> alloc tick
    rejected_inflight = 0  # admissions ended by a mid-flight Reject
    failed_inflight = 0  # admissions ended by a terminal Failed
    health = "healthy"  # degradation bracket state (law 11)

    def life(req):
        return lives.setdefault(
            req,
            {
                "enq": None,
                # first admission tick — tick-order law anchor (TTFT may
                # precede a later re-admission after preemption)
                "first_admit": None,
                # current-life admission tick; cleared by Preempt so a
                # re-admit is legal while a double-admit still trips
                "admit": None,
                "first": None,
                "last": None,
                "finish": None,
                "tokens": 0,
                "finish_tokens": None,
                "rejected": False,
                "cancelled": False,
                "deadline_miss": False,
                "faults": 0,
                "retries": 0,
                "failed": False,
            },
        )

    for i, ev in enumerate(events):
        kind = ev.get("kind")
        if kind not in KINDS:
            bad(f"event {i}: unknown kind {kind!r}")
            continue
        missing = [f for f in ("tick",) + KINDS[kind] if f not in ev]
        if missing:
            bad(f"event {i} ({kind}): missing fields {missing}")
            continue
        t = ev["tick"]
        # law 10: Failed is terminal — nothing may name the request after
        if "req" in KINDS[kind] and kind != "Failed":
            prior = lives.get(ev["req"])
            if prior is not None and prior["failed"]:
                bad(f"req {ev['req']}: {kind} after Failed (failure is terminal)")
        if kind == "Enqueue":
            r["enqueued"] += 1
            l = life(ev["req"])
            if l["enq"] is not None:
                bad(f"req {ev['req']}: enqueued twice")
            l["enq"] = t
        elif kind == "Requeue":
            r["requeues"] += 1
        elif kind == "Admit":
            r["admitted"] += 1
            row, req = ev["row"], ev["req"]
            if row in rows:
                bad(f"row {row}: admit req {req} over live req {rows[row]}")
            rows[row] = req
            l = life(req)
            if l["admit"] is not None:
                bad(f"req {req}: admitted twice")
            if l["cancelled"]:
                bad(f"req {req}: admitted after cancel")
            if l["enq"] is None:
                bad(f"req {req}: admitted, never enqueued")
            elif t < l["enq"]:
                bad(f"req {req}: admit tick {t} < enqueue {l['enq']}")
            if l["first_admit"] is None:
                l["first_admit"] = t
            l["admit"] = t
        elif kind == "Reject":
            r["rejected"] += 1
            l = life(ev["req"])
            l["rejected"] = True
            if l["admit"] is not None:
                rejected_inflight += 1
            # mid-flight rejection frees the row
            for row, occ in list(rows.items()):
                if occ == ev["req"]:
                    del rows[row]
        elif kind == "DecodeStep":
            r["tokens"] += 1
            row = ev["row"]
            if row not in rows:
                bad(f"tick {t}: token on unoccupied row {row}")
                continue
            l = life(rows[row])
            l["tokens"] += 1
            # exact Server::step replication: TTFT on the first token, an
            # ITL gap for every token with a predecessor
            if l["first"] is None:
                l["first"] = t
                enq = l["enq"] if l["enq"] is not None else t
                r["ttft_ticks"].append(t - min(enq, t))
            if l["last"] is not None:
                r["itl_ticks"].append(t - min(l["last"], t))
            l["last"] = t
        elif kind == "Finish":
            r["finished"] += 1
            req, row = ev["req"], ev["row"]
            occ = rows.pop(row, None)
            if occ is None:
                bad(f"req {req}: finish on unoccupied row {row}")
            elif occ != req:
                bad(f"row {row}: finish req {req} but occupant is req {occ}")
            l = life(req)
            l["finish"] = t
            l["finish_tokens"] = ev["tokens"]
        elif kind == "Preempt":
            r["preempted"] += 1
            req, row = ev["req"], ev["row"]
            occ = rows.pop(row, None)
            if occ is None:
                bad(f"req {req}: preempt on unoccupied row {row}")
            elif occ != req:
                bad(f"row {row}: preempt req {req} but occupant is req {occ}")
            l = life(req)
            if l["admit"] is None:
                bad(f"req {req}: preempted while not admitted")
            if ev["tokens"] != l["tokens"]:
                bad(
                    f"req {req}: Preempt says {ev['tokens']} tokens but "
                    f"life sampled {l['tokens']}"
                )
            # the discarded stream is conserved into preempted_tokens; the
            # re-run life starts with a clean token/ITL slate (TTFT was
            # recorded once, on the first-ever token)
            r["preempted_tokens"] += l["tokens"]
            l["tokens"] = 0
            l["last"] = None
            l["admit"] = None
        elif kind == "Cancel":
            r["cancelled"] += 1
            l = life(ev["req"])
            if l["enq"] is None:
                bad(f"req {ev['req']}: cancelled, never enqueued")
            if l["cancelled"]:
                bad(f"req {ev['req']}: cancelled twice")
            if l["admit"] is not None:
                bad(f"req {ev['req']}: cancelled while in flight")
            if l["finish"] is not None:
                bad(f"req {ev['req']}: cancelled after finish")
            l["cancelled"] = True
        elif kind == "DeadlineMiss":
            r["deadline_misses"] += 1
            l = life(ev["req"])
            if l["deadline_miss"]:
                bad(f"req {ev['req']}: deadline missed twice")
            l["deadline_miss"] = True
        elif kind == "Fault":
            r["faults"] += 1
            req, row = ev["req"], ev["row"]
            l = life(req)
            if l["admit"] is None:
                bad(f"req {req}: fault while not admitted")
            elif rows.get(row) != req:
                bad(f"req {req}: fault on row {row} it does not occupy")
            l["faults"] += 1
        elif kind == "Retry":
            r["retries"] += 1
            l = life(ev["req"])
            if l["faults"] != l["retries"] + 1:
                bad(
                    f"req {ev['req']}: retry without a pending fault "
                    f"({l['faults']} faults, {l['retries']} retries)"
                )
            elif ev["attempt"] != l["retries"] + 1:
                bad(
                    f"req {ev['req']}: Retry says attempt {ev['attempt']} "
                    f"but this is retry {l['retries'] + 1}"
                )
            l["retries"] += 1
        elif kind == "Failed":
            r["failed"] += 1
            req = ev["req"]
            l = life(req)
            if l["enq"] is None:
                bad(f"req {req}: failed, never enqueued")
            if l["cancelled"]:
                bad(f"req {req}: failed after cancel")
            if l["finish"] is not None:
                bad(f"req {req}: failed after finish")
            if ev["tokens"] != l["tokens"]:
                bad(
                    f"req {req}: Failed says {ev['tokens']} tokens but "
                    f"life sampled {l['tokens']}"
                )
            if ev["attempts"] != l["faults"]:
                bad(
                    f"req {req}: Failed says {ev['attempts']} attempts but "
                    f"life took {l['faults']} faults"
                )
            if l["admit"] is not None:
                # in-flight failure: closes the admission (ledger), frees
                # the row, conserves the discarded stream (like Preempt)
                if l["faults"] != l["retries"] + 1:
                    bad(
                        f"req {req}: retry ledger broken at Failed "
                        f"({l['faults']} faults != {l['retries']} retries + 1)"
                    )
                failed_inflight += 1
                for row, occ in list(rows.items()):
                    if occ == req:
                        del rows[row]
            elif l["faults"] != l["retries"]:
                bad(
                    f"req {req}: retry ledger broken at queue Failed "
                    f"({l['faults']} faults != {l['retries']} retries)"
                )
            r["failed_tokens"] += l["tokens"]
            l["tokens"] = 0
            l["last"] = None
            l["admit"] = None
            l["failed"] = True
        elif kind == "Degrade":
            r["degrades"] += 1
            level = ev["level"]
            if level not in ("degraded", "failing"):
                bad(f"tick {t}: unknown degrade level {level!r}")
            elif level == "degraded" and health != "healthy":
                bad(f"tick {t}: degrade to degraded while {health}")
            elif level == "failing" and health == "failing":
                bad(f"tick {t}: degrade to failing while already failing")
            else:
                health = level
        elif kind == "Recover":
            if health == "healthy":
                bad(f"tick {t}: recover while healthy")
            elif health == "failing":
                bad(f"tick {t}: recover from failing (failing is terminal)")
            else:
                health = "healthy"
        elif kind == "BlockAlloc":
            if ev["block"] in live_blocks:
                bad(f"block {ev['block']}: allocated while live")
            live_blocks[ev["block"]] = t
        elif kind == "BlockFree":
            if live_blocks.pop(ev["block"], None) is None:
                bad(f"block {ev['block']}: freed while free")
        elif kind == "CowCopy":
            r["cow_copies"] += 1
        elif kind == "PrefixHit":
            r["prefix_hits"] += 1
        elif kind == "VerifyRound":
            r["verify_rounds"] += 1
            if ev["accepted"] > ev["k"]:
                bad(f"tick {t}: verify accepted {ev['accepted']} > drafted {ev['k']}")
        elif kind == "SessionRun":
            r["session_runs"] += 1
        elif kind == "Gauge":
            g = r["gauges"].setdefault(ev["name"], [])
            g.append(ev["value"])
        # PrefillWindow / Rewind / Evict: informational, no law attaches

    for req, l in sorted(lives.items()):
        if l["deadline_miss"] and l["finish"] is None:
            bad(f"req {req}: deadline miss without a finish")
        if not l["failed"] and l["faults"] != l["retries"]:
            bad(
                f"req {req}: retry ledger broken at end of trace "
                f"({l['faults']} faults, {l['retries']} retries, no "
                "terminal Failed)"
            )
        if l["admit"] is None:
            if (
                not l["rejected"]
                and not l["cancelled"]
                and not l["failed"]
                and l["enq"] is not None
            ):
                bad(f"req {req}: enqueued but never admitted or rejected")
            continue
        if l["enq"] is None:
            continue  # already flagged: admitted, never enqueued
        if l["rejected"]:
            continue
        if l["finish"] is None:
            bad(f"req {req}: admitted but never finished")
            continue
        if l["first"] is None:
            bad(f"req {req}: finished without a first token")
            continue
        # tick order anchors on the *first* admission: TTFT is recorded
        # once per request, and a preempted request's final admit tick may
        # legitimately postdate its first-ever token
        enq = l["enq"]
        admit0 = l["first_admit"] if l["first_admit"] is not None else l["admit"]
        if not (enq <= admit0 <= l["first"] <= l["finish"]):
            bad(
                f"req {req}: tick order broken (enq {enq} <= admit "
                f"{admit0} <= first {l['first']} <= finish {l['finish']})"
            )
        if l["finish_tokens"] is not None and l["finish_tokens"] != l["tokens"]:
            bad(
                f"req {req}: {l['tokens']} DecodeStep tokens but Finish "
                f"says {l['finish_tokens']}"
            )
    # admission ledger: every admission ends in exactly one of finish /
    # preempt / mid-flight reject / terminal failure
    if r["admitted"] != r["finished"] + r["preempted"] + rejected_inflight + failed_inflight:
        bad(
            f"admission ledger broken: {r['admitted']} admits != "
            f"{r['finished']} finishes + {r['preempted']} preempts + "
            f"{rejected_inflight} mid-flight rejects + "
            f"{failed_inflight} fails"
        )
    if health == "degraded":
        bad("degradation never closed: trace ends degraded, not failing")
    if rows:
        stuck = ", ".join(f"{row}:req {req}" for row, req in sorted(rows.items()))
        bad(f"rows still occupied at end of trace: {stuck}")
    r["live_blocks"] = len(live_blocks)
    return r


def check(report, stats, other):
    """The --check gate: conservation + bit-for-bit percentile agreement
    with the serverStats block the exporter embedded."""
    errs = list(report["violations"])
    if other.get("dropped", 0):
        errs.append(
            f"ring dropped {other['dropped']} events — conservation cannot "
            "be audited; raise the sink capacity"
        )
    if report["cow_copies"] != 0:
        errs.append(
            f"{report['cow_copies']} copy-on-write forks in a serve trace "
            "(the Sec 2f share-only-full-blocks invariant)"
        )
    if stats is None:
        errs.append("trace carries no serverStats block (need --check input "
                    "from `serve --trace`)")
        return errs
    for key, got in [
        ("served", report["finished"]),
        ("rejected", report["rejected"]),
        ("total_tokens", report["tokens"]),
        ("preempted", report["preempted"]),
        ("cancelled", report["cancelled"]),
        ("deadline_misses", report["deadline_misses"]),
        ("failed", report["failed"]),
        ("retries", report["retries"]),
    ]:
        want = stats.get(key)
        if want is not None and got != want:
            errs.append(f"{key}: trace replay says {got}, serverStats says {want}")
    want = stats.get("goodput")
    if want is not None:
        # bit-for-bit mirror of ServerStats::goodput: (served -
        # deadline_misses) / max(served + cancelled + failed, 1), IEEE
        # f64 division
        got = (report["finished"] - report["deadline_misses"]) / float(
            max(report["finished"] + report["cancelled"] + report["failed"], 1)
        )
        if got != want:
            errs.append(
                f"goodput: recomputed {got!r} != exported {want!r}"
            )
    for key, ticks in [("ttft", report["ttft_ticks"]), ("itl", report["itl_ticks"])]:
        for p in (50, 95):
            want = stats.get(f"{key}_tick_p{p}")
            if want is None:
                continue
            got = percentile(ticks, float(p))
            # bit-for-bit: same vector, same interpolation, same IEEE ops
            if got != want:
                errs.append(
                    f"{key} p{p}: recomputed {got!r} != exported {want!r} "
                    f"(n={len(ticks)})"
                )
    want_blocks = stats.get("blocks_in_use")
    if want_blocks is not None and report["live_blocks"] != want_blocks:
        errs.append(
            f"block ledger: {report['live_blocks']} blocks live at end of "
            f"trace, serverStats says {want_blocks} in use"
        )
    return errs


def summarize(report, stats, other, path):
    print(f"{path}: clock={other.get('clock', '?')} "
          f"schema={other.get('schema_version', '?')} "
          f"dropped={other.get('dropped', 0)}")
    print(
        f"  requests: {report['enqueued']} enqueued, {report['admitted']} "
        f"admitted, {report['finished']} finished, {report['rejected']} "
        f"rejected ({report['requeues']} requeues)"
    )
    print(
        f"  slo: {report['preempted']} preempted "
        f"({report['preempted_tokens']} tokens discarded), "
        f"{report['cancelled']} cancelled, {report['deadline_misses']} "
        f"deadline misses"
    )
    print(
        f"  chaos: {report['faults']} faults, {report['retries']} retries, "
        f"{report['failed']} failed ({report['failed_tokens']} tokens "
        f"discarded), {report['degrades']} degrades"
    )
    print(
        f"  tokens: {report['tokens']} sampled; {report['verify_rounds']} "
        f"verify rounds, {report['prefix_hits']} prefix hits, "
        f"{report['cow_copies']} cow copies, {report['live_blocks']} blocks "
        f"live at end, {report['session_runs']} session runs"
    )
    for key, ticks in [("ttft", report["ttft_ticks"]), ("itl", report["itl_ticks"])]:
        p50, p95 = percentile(ticks, 50.0), percentile(ticks, 95.0)
        print(f"  {key}: n={len(ticks)} p50={p50:g} p95={p95:g} ticks")
    for name, vals in sorted(report["gauges"].items()):
        print(f"  gauge {name}: n={len(vals)} max={max(vals):g}")
    if stats is not None:
        print(f"  serverStats: {json.dumps(stats, sort_keys=True)}")
    if report["violations"]:
        print(f"  VIOLATIONS ({len(report['violations'])}):")
        for v in report["violations"]:
            print(f"    - {v}")


def main(argv):
    argv = argv[1:]
    checking = "--check" in argv
    paths = [a for a in argv if not a.startswith("--")]
    if len(paths) != 1:
        print(__doc__.strip().splitlines()[0])
        print("usage: trace_report.py [--check] trace.json|trace.jsonl")
        return 2
    events, stats, other = load(paths[0])
    report = audit(events)
    if checking:
        errs = check(report, stats, other)
        if errs:
            print(f"trace_report: {paths[0]} FAILED ({len(errs)} problems):")
            for e in errs:
                print(f"  - {e}")
            return 1
        print(
            f"trace_report: {paths[0]} OK — {len(events)} events, "
            f"{report['finished']} requests conserved, percentiles match "
            "serverStats bit-for-bit"
        )
        return 0
    summarize(report, stats, other, paths[0])
    return 1 if report["violations"] else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))

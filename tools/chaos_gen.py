#!/usr/bin/env python3
"""Deterministic fault-plan generator — bit-exact mirror of
`rust/src/chaos.rs` (stdlib only).

Like `workload_gen.py` for arrivals, this mirrors the *plan*, not the
live engine: both sides pregenerate the full fault schedule as a pure
function of `(scenario, ticks, seed)` from the repo PCG64-DXSM stream
using integer draws only, so `tools/slo_sim.py` can replay the exact
faults `chaos::ChaosEngine` injects and `python/tests/test_chaos_sched.py`
pre-validates every `serve.rs` chaos test without cargo. The loramlint
contract-mirror pins both `CHAOS_SCENARIOS` and `FAULT_KINDS` below
against the Rust consts (names AND order); the golden-plan test pins the
first draws of every scenario at seed 9 on both sides.

Draw order per scenario is part of the contract (documented again in the
Rust arms):

  fault-storm:  per tick: coin below(3); on 0: kind below(4), row below(8)
  decode-flaky: per tick: coin below(4); on 0: kind 0, row below(8)
  admit-flaky:  per tick: coin below(3); on 0: kind 1, row 0
  pool-squeeze: per tick: coin below(3); on 0: kind 2, row 0
  stuck-stall:  per tick: coin below(6); on 0: kind 3, row 0
  device-loss:  single draw: tick below(ticks), kind 4, row 0

Rows are drawn in [0, 8) regardless of the target engine's batch size; a
fault aimed at an out-of-range or unoccupied row is a harmless lost tick
by design (the schedule stays pure).

Usage:
    python3 tools/chaos_gen.py SCENARIO [--ticks T] [--seed S] [--out F]
    python3 tools/chaos_gen.py --list
"""

import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from workload_gen import Rng  # noqa: E402

# Fault taxonomy — must equal rust/src/chaos.rs::FAULT_KINDS (the
# loramlint `fault-kinds` contract pair). Index is the plan's `kind_ix`.
FAULT_KINDS = [
    "decode-transient",
    "admit-fail",
    "pool-exhaust",
    "stuck-tick",
    "device-lost",
]

# Scenario catalog — must equal rust/src/chaos.rs::CHAOS_SCENARIOS (the
# loramlint `chaos-scenarios` contract pair).
CHAOS_SCENARIOS = [
    "fault-storm",
    "decode-flaky",
    "admit-flaky",
    "pool-squeeze",
    "stuck-stall",
    "device-loss",
]


def generate(scenario, ticks, seed):
    """Mirror of chaos.rs::generate — same Rng stream, same draw order
    per arm. Returns a list of {"tick", "kind_ix", "row"} dicts sorted by
    tick (generation order is already tick-ascending)."""
    if ticks < 1:
        raise ValueError("chaos plan needs ticks >= 1")
    rng = Rng(seed)
    plan = []

    def push(tick, kind_ix, row):
        plan.append({"tick": tick, "kind_ix": kind_ix, "row": row})

    if scenario == "fault-storm":
        # the A/B headline: ~1/3 of ticks fault, any transient kind
        # (device-lost excluded — the storm must be survivable)
        for t in range(ticks):
            if rng.below(3) == 0:
                kind = rng.below(4)
                push(t, kind, rng.below(8))
    elif scenario == "decode-flaky":
        for t in range(ticks):
            if rng.below(4) == 0:
                push(t, 0, rng.below(8))
    elif scenario == "admit-flaky":
        for t in range(ticks):
            if rng.below(3) == 0:
                push(t, 1, 0)
    elif scenario == "pool-squeeze":
        for t in range(ticks):
            if rng.below(3) == 0:
                push(t, 2, 0)
    elif scenario == "stuck-stall":
        for t in range(ticks):
            if rng.below(6) == 0:
                push(t, 3, 0)
    elif scenario == "device-loss":
        push(rng.below(ticks), 4, 0)
    else:
        raise ValueError(
            f"unknown chaos scenario {scenario!r} "
            f"(expected one of {CHAOS_SCENARIOS})"
        )
    return plan


def main(argv):
    argv = argv[1:]
    if "--list" in argv:
        for s in CHAOS_SCENARIOS:
            print(s)
        return 0
    pos = [a for a in argv if not a.startswith("-")]
    scenario = pos[0] if pos else None
    if scenario is None:
        print(__doc__.strip().splitlines()[0])
        print("usage: chaos_gen.py SCENARIO [--ticks T] [--seed S] [--out F]")
        print(f"scenarios: {', '.join(CHAOS_SCENARIOS)}")
        return 2

    def opt(name, default):
        if name in argv:
            return int(argv[argv.index(name) + 1])
        return default

    ticks = opt("--ticks", 64)
    seed = opt("--seed", 0)
    try:
        plan = generate(scenario, ticks, seed)
    except ValueError as e:
        print(f"chaos_gen: {e}")
        return 2
    doc = {
        "scenario": scenario,
        "ticks": ticks,
        "seed": seed,
        "kinds": FAULT_KINDS,
        "faults": plan,
    }
    if "--out" in argv:
        path = argv[argv.index("--out") + 1]
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"chaos_gen: wrote {len(plan)} {scenario!r} faults to {path}")
    else:
        json.dump(doc, sys.stdout, indent=1)
        print()
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))

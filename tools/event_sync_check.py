#!/usr/bin/env python3
"""Fail CI when the trace-event schema drifts between Rust and Python.

The trace-event vocabulary lives in two places that cannot share code:

  * `rust/src/obs/trace.rs` — the `Event` enum (one variant per line,
    struct-style fields), which is what the serving stack emits, and
  * `tools/trace_report.py` — the `KINDS` table (kind -> payload fields),
    which is what the offline auditor validates against.

This script parses both *source texts* and diffs variant names, order,
and field lists. Adding an event kind (or a field) to one side without
the other exits nonzero with the exact diff, so the schema cannot drift
silently between a Rust refactor and the Python audit.

Usage:
    python3 tools/event_sync_check.py          # from the repo root
    python3 tools/event_sync_check.py <repo>   # explicit repo root
"""

import os
import re
import sys


def parse_rust_enum(path):
    """Return [(variant, [fields...])] from `pub enum Event { ... }`."""
    with open(path) as f:
        src = f.read()
    m = re.search(r"pub enum Event \{(.*?)\n\}", src, re.S)
    if not m:
        raise SystemExit(f"{path}: could not find `pub enum Event {{ ... }}`")
    variants = []
    for line in m.group(1).splitlines():
        line = line.strip()
        vm = re.match(r"([A-Z]\w*)\s*\{([^}]*)\}", line)
        if not vm:
            continue  # doc comments, attributes, blank lines
        fields = re.findall(r"(\w+)\s*:", vm.group(2))
        variants.append((vm.group(1), fields))
    if not variants:
        raise SystemExit(f"{path}: parsed zero variants — is the enum still "
                         "one-variant-per-line?")
    return variants


def parse_rust_kinds_const(path):
    """Return the KINDS const string list (the runtime kind table)."""
    with open(path) as f:
        src = f.read()
    m = re.search(r"pub const KINDS[^=]*=\s*&\[(.*?)\];", src, re.S)
    if not m:
        raise SystemExit(f"{path}: could not find `pub const KINDS`")
    return re.findall(r'"(\w+)"', m.group(1))


def parse_python_kinds(path):
    """Return [(kind, [fields...])] from trace_report.py's KINDS dict."""
    with open(path) as f:
        src = f.read()
    m = re.search(r"^KINDS = \{(.*?)\n\}", src, re.S | re.M)
    if not m:
        raise SystemExit(f"{path}: could not find `KINDS = {{ ... }}`")
    kinds = []
    for line in m.group(1).splitlines():
        km = re.match(r'\s*"(\w+)":\s*\(([^)]*)\)', line)
        if not km:
            continue
        fields = re.findall(r'"(\w+)"', km.group(2))
        kinds.append((km.group(1), fields))
    if not kinds:
        raise SystemExit(f"{path}: parsed zero kinds — is KINDS still "
                         "one-kind-per-line?")
    return kinds


def main(argv):
    root = argv[1] if len(argv) > 1 else os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))
    trace_rs = os.path.join(root, "rust", "src", "obs", "trace.rs")
    report_py = os.path.join(root, "tools", "trace_report.py")
    rust = parse_rust_enum(trace_rs)
    rust_const = parse_rust_kinds_const(trace_rs)
    py = parse_python_kinds(report_py)

    errs = []
    rust_names = [n for n, _ in rust]
    py_names = [n for n, _ in py]
    if rust_names != rust_const:
        errs.append(
            "trace.rs: `Event` variants and the `KINDS` const disagree:\n"
            f"  enum : {rust_names}\n  const: {rust_const}"
        )
    if rust_names != py_names:
        only_rust = [n for n in rust_names if n not in py_names]
        only_py = [n for n in py_names if n not in rust_names]
        detail = []
        if only_rust:
            detail.append(f"only in trace.rs: {only_rust}")
        if only_py:
            detail.append(f"only in trace_report.py: {only_py}")
        if not detail:
            detail.append(f"order differs:\n  rust:   {rust_names}\n"
                          f"  python: {py_names}")
        errs.append("event kinds drifted — " + "; ".join(detail))
    else:
        for (name, rf), (_, pf) in zip(rust, py):
            if rf != pf:
                errs.append(
                    f"{name}: payload fields drifted — trace.rs has {rf}, "
                    f"trace_report.py has {pf}"
                )

    if errs:
        print(f"event_sync_check: FAILED ({len(errs)} problems):")
        for e in errs:
            print(f"  - {e}")
        return 1
    print(
        f"event_sync_check: OK — {len(rust)} event kinds in sync between "
        "rust/src/obs/trace.rs and tools/trace_report.py"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))

#!/usr/bin/env python3
"""Fail CI when the trace-event schema drifts between Rust and Python.

Thin shim: the actual check moved into the loramlint suite as the
`event-kinds` contract of the contract-mirror pass
(`tools/loramlint/contract_mirror.py`), alongside the other
cross-language pairs (chunk ladder, paged geometry, schema version,
metrics keys). This wrapper keeps the historical CLI so existing
invocations — `python3 tools/event_sync_check.py [repo_root]` — and
ci.sh muscle memory keep working.

For the full suite: `python3 tools/loramlint rust/src`.
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from loramlint.contract_mirror import (  # noqa: E402
    diff_event_kinds,
    parse_python_kinds,
    parse_rust_event_enum,
    parse_rust_kinds_const,
)


# path-based wrappers, preserving this script's historical API (the
# loramlint extractors take source text, not paths)
def parse_rust_enum(path):
    with open(path) as f:
        return parse_rust_event_enum(f.read(), path)


def parse_rust_kinds(path):
    with open(path) as f:
        return parse_rust_kinds_const(f.read(), path)


def parse_py_kinds(path):
    with open(path) as f:
        return parse_python_kinds(f.read(), path)


def main(argv):
    root = argv[1] if len(argv) > 1 else os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))
    trace_rs = os.path.join(root, "rust", "src", "obs", "trace.rs")
    report_py = os.path.join(root, "tools", "trace_report.py")
    with open(trace_rs) as f:
        trace_src = f.read()
    with open(report_py) as f:
        report_src = f.read()
    try:
        rust = parse_rust_event_enum(trace_src, trace_rs)
        rust_const = parse_rust_kinds_const(trace_src, trace_rs)
        py = parse_python_kinds(report_src, report_py)
    except Exception as e:  # extraction anchors gone
        raise SystemExit(str(e))
    errs = diff_event_kinds(rust, rust_const, py)
    if errs:
        print(f"event_sync_check: FAILED ({len(errs)} problems):")
        for e in errs:
            print(f"  - {e}")
        return 1
    print(
        f"event_sync_check: OK — {len(rust)} event kinds in sync between "
        "rust/src/obs/trace.rs and tools/trace_report.py "
        "(via loramlint contract-mirror)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))

#!/usr/bin/env python3
"""Adversarial workload generator — bit-exact mirror of
`rust/src/workload.rs` (stdlib only).

Both sides build each scenario from the repo PCG64-DXSM generator using
*integer draws only*, and the per-request draw order is documented in the
Rust arms as part of the contract — so `generate(scenario, n, seed)` here
reproduces the Rust request stream field-for-field. The loramlint
contract-mirror pins `SCENARIOS` below against `workload.rs::SCENARIOS`;
renaming a scenario on one side fails the lint, and the golden-stream
test in `python/tests/test_slo_sched.py` pins the first few draws of
every scenario against the values `rust/src/workload.rs` asserts in its
own unit tests.

Usage:
    python3 tools/workload_gen.py SCENARIO [-n N] [--seed S] [--out F]
    python3 tools/workload_gen.py --list
"""

import json
import sys

MASK64 = (1 << 64) - 1
MASK128 = (1 << 128) - 1

# Scenario catalog — must equal rust/src/workload.rs::SCENARIOS (the
# loramlint `workload-scenarios` contract pair).
SCENARIOS = [
    "steady",
    "bursty-heavytail",
    "adapter-skew",
    "deadline-storm",
    "rejection-storm",
    "faults",
]

# Priority names in Rust enum order (Low < Normal < High) — index is the
# comparison key, mirroring `serve::Priority`'s derived Ord.
PRIORITIES = ("low", "normal", "high")


class Rng:
    """PCG64-DXSM, bit-identical to rust/src/util/rng.rs::Rng (wrapping
    u128/u64 arithmetic emulated with masks)."""

    MUL = 0x2360ED051FC65DA44385DF649FCCF645

    def __init__(self, seed):
        self.state = 0
        self.inc = (((seed & MASK64) << 1) | 1) & MASK128
        self.next_u64()
        self.state = (self.state + (0x9E3779B97F4A7C15 ^ (seed & MASK64))) & MASK128
        self.next_u64()

    def next_u64(self):
        self.state = (self.state * self.MUL + self.inc) & MASK128
        hi = (self.state >> 64) & MASK64
        lo = (self.state & MASK64) | 1
        hi ^= hi >> 32
        hi = (hi * 0xDA942042E4DD58B5) & MASK64
        hi ^= hi >> 48
        return (hi * lo) & MASK64

    def below(self, n):
        """Uniform integer in [0, n) — Lemire's method on 64 bits."""
        assert n > 0
        return (self.next_u64() * n) >> 64


def heavy_tail(rng, base, cap):
    """Mirror of workload.rs::heavy_tail: uniform in [base, 2*base), then
    doubled with probability 1/4 per round until cap. The `len < cap`
    short-circuit means no coin is drawn once cap is reached."""
    length = base + rng.below(base)
    while length < cap and rng.below(4) == 0:
        length *= 2
    return min(length, cap)


def generate(scenario, n, seed):
    """Mirror of workload.rs::generate — same Rng stream, same draw order
    per arm. Returns a list of request dicts; `priority` is one of
    PRIORITIES, `deadline_ticks`/`adapter_ix` are None when absent."""
    rng = Rng(seed)
    out = []
    tick = 0
    for i in range(n):
        if scenario == "steady":
            req = {
                "arrival_tick": i,
                "prompt_len": 8 + rng.below(8),
                "max_new": 4 + rng.below(4),
                "priority": "normal",
                "deadline_ticks": None,
                "adapter_ix": None,
            }
        elif scenario == "bursty-heavytail":
            if rng.below(4) == 0:
                tick += 1 + rng.below(6)
            prompt_len = heavy_tail(rng, 8, 512)
            max_new = heavy_tail(rng, 4, 64)
            cls = rng.below(10)
            priority = "high" if cls < 2 else ("normal" if cls < 8 else "low")
            deadline = 8 + rng.below(8) if priority == "high" else None
            req = {
                "arrival_tick": tick,
                "prompt_len": prompt_len,
                "max_new": max_new,
                "priority": priority,
                "deadline_ticks": deadline,
                "adapter_ix": None,
            }
        elif scenario == "adapter-skew":
            tick += 1 if rng.below(2) == 0 else 0
            hot = rng.below(11) < 10
            req = {
                "arrival_tick": tick,
                "prompt_len": 8 + rng.below(8),
                "max_new": 2 + rng.below(6),
                "priority": "normal",
                "deadline_ticks": None,
                "adapter_ix": 0 if hot else 1,
            }
        elif scenario == "deadline-storm":
            if i > 0 and i % 8 == 0:
                tick += 4
            req = {
                "arrival_tick": tick,
                "prompt_len": 8 + rng.below(8),
                "max_new": 2 + rng.below(4),
                "priority": "normal",
                "deadline_ticks": 1 + rng.below(6),
                "adapter_ix": None,
            }
        elif scenario == "rejection-storm":
            req = {
                "arrival_tick": 0,
                "prompt_len": heavy_tail(rng, 64, 2048),
                "max_new": 1 + rng.below(4),
                "priority": "normal",
                "deadline_ticks": None,
                "adapter_ix": None,
            }
        elif scenario == "faults":
            if rng.below(3) == 0:
                tick += 1 + rng.below(4)
            prompt_len = 6 + rng.below(12)
            max_new = 3 + rng.below(6)
            priority = "high" if rng.below(8) == 0 else "normal"
            deadline = 12 + rng.below(10) if priority == "high" else None
            req = {
                "arrival_tick": tick,
                "prompt_len": prompt_len,
                "max_new": max_new,
                "priority": priority,
                "deadline_ticks": deadline,
                "adapter_ix": None,
            }
        else:
            raise ValueError(
                f"unknown workload scenario {scenario!r} "
                f"(expected one of {SCENARIOS})"
            )
        out.append(req)
    return out


def main(argv):
    argv = argv[1:]
    if "--list" in argv:
        for s in SCENARIOS:
            print(s)
        return 0
    pos = [a for a in argv if not a.startswith("-")]
    scenario = pos[0] if pos else None
    if scenario is None:
        print(__doc__.strip().splitlines()[0])
        print("usage: workload_gen.py SCENARIO [-n N] [--seed S] [--out F]")
        print(f"scenarios: {', '.join(SCENARIOS)}")
        return 2

    def opt(name, default):
        if name in argv:
            return int(argv[argv.index(name) + 1])
        return default

    n = opt("-n", 64)
    seed = opt("--seed", 0)
    try:
        reqs = generate(scenario, n, seed)
    except ValueError as e:
        print(f"workload_gen: {e}")
        return 2
    doc = {"scenario": scenario, "n": n, "seed": seed, "requests": reqs}
    if "--out" in argv:
        path = argv[argv.index("--out") + 1]
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"workload_gen: wrote {n} {scenario!r} requests to {path}")
    else:
        json.dump(doc, sys.stdout, indent=1)
        print()
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))

#!/usr/bin/env python3
"""Python tick model of the SLO serving scheduler (stdlib only).

An exact mirror of `rust/src/serve.rs::Server` over the instant-prefill
`SimEngine` (one marker token per row per tick, finish at `max_new`):
same admission pick rule, same fairness-cap skip, same one-victim-
per-tick preemption (lowest class, youngest enqueue, lowest row on
ties), same deadline cancellation and miss accounting, and the same
pre-/post-increment tick stamping — so for any workload from
`tools/workload_gen.py` the event stream, the TTFT/ITL tick vectors and
every counter equal what the Rust scheduler produces, event for event.
`python/tests/test_slo_sched.py` pins the same scenario numbers the
`serve.rs` unit tests assert, pre-validating them without cargo.

The emitted trace document has the `serve --trace` shape (`loramEvents`
+ `serverStats`), so `tools/trace_report.py --check` audits the model's
streams under the full conservation-law suite — the `slo-sim` CI lane.

Usage:
    python3 tools/slo_sim.py SCENARIO [-n N] [--seed S] [--batch B]
            [--slo] [--fair-rows K] [--out trace.json]
    python3 tools/slo_sim.py --ab SCENARIO [-n N] [--seed S] [--batch B]
        # runs FIFO vs SLO on the same stream; exit 1 unless SLO wins
        # on goodput-under-SLO
"""

import json
import math
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from workload_gen import PRIORITIES, SCENARIOS, generate  # noqa: E402

TRACE_SCHEMA_VERSION = 1


def percentile(xs, p):
    """rank = (p/100)*(n-1) lerp — same as util::stats / trace_report."""
    if not xs:
        return 0.0
    v = sorted(xs)
    rank = (p / 100.0) * (len(v) - 1)
    lo, hi = math.floor(rank), math.ceil(rank)
    if lo == hi:
        return float(v[lo])
    return v[lo] + (rank - lo) * (v[hi] - v[lo])


def _prio(name):
    return PRIORITIES.index(name)


class SimServer:
    """Mirror of `Server<SimEngine>` with instant admissions: the
    `prefill_begin` path always completes, `can_admit` is always true,
    and decode emits one token per occupied row per tick in row order."""

    def __init__(self, batch, slo=False, fair_rows=None):
        self.batch = batch
        self.rows = [None] * batch
        self.queue = []
        self.next_id = 0
        self.ticks = 0
        self.slo = slo
        # mirror set_adapter_fair_cap's cap.max(1) clamp
        self.fair_rows = None if fair_rows is None else max(fair_rows, 1)
        self.trace_tick = 0
        self.events = []
        self.admitted = 0
        self.served = 0
        self.rejected = 0
        self.preempted = 0
        self.cancelled = 0
        self.deadline_misses = 0
        self.total_tokens = 0
        self.ttft_ticks = []
        self.itl_ticks = []
        # req id -> (priority name, ttft ticks) for A/B reporting
        self.req_ttft = {}

    def emit(self, kind, **fields):
        self.events.append(
            {"kind": kind, "tick": self.trace_tick, "wall_ms": 0.0, **fields}
        )

    def pending(self):
        return len(self.queue)

    def in_flight(self):
        return sum(1 for f in self.rows if f is not None)

    def free_rows(self):
        return sum(1 for f in self.rows if f is None)

    def enqueue(self, req):
        """`req` is a workload_gen request dict; returns the id.
        Mirrors enqueue_slo: the absolute deadline is `ticks + rel`."""
        rid = self.next_id
        self.next_id += 1
        rel = req.get("deadline_ticks")
        self.queue.append({
            "id": rid,
            "max_new": max(req["max_new"], 1),  # SimRow budget clamp
            "priority": req.get("priority", "normal"),
            "deadline_tick": None if rel is None else self.ticks + rel,
            "adapter_ix": req.get("adapter_ix"),
            "enq_tick": self.ticks,
            "ttft_done": False,
        })
        self.trace_tick = self.ticks
        self.emit("Enqueue", req=rid)
        return rid

    def _pick_ix(self):
        if not self.slo and self.fair_rows is None:
            return 0 if self.queue else None
        best = None  # (priority ordinal, index)
        for ix, q in enumerate(self.queue):
            if self.fair_rows is not None:
                lane = sum(
                    1 for f in self.rows
                    if f is not None and f["adapter_ix"] == q["adapter_ix"]
                )
                if lane >= self.fair_rows:
                    continue
            if best is None or (self.slo and _prio(q["priority"]) > best[0]):
                best = (_prio(q["priority"]), ix)
        return None if best is None else best[1]

    def _cancel_expired(self):
        now = self.ticks
        kept = []
        for q in self.queue:
            d = q["deadline_tick"]
            if d is not None and d <= now:
                self.emit("Cancel", req=q["id"])
                self.cancelled += 1
            else:
                kept.append(q)
        self.queue = kept

    def _preempt(self, row):
        f = self.rows[row]
        self.rows[row] = None
        self.emit("Preempt", req=f["id"], row=row, tokens=f["tokens"])
        self.preempted += 1
        # back to the queue front with the original clocks; the next life
        # restarts its token count but never re-records TTFT
        self.queue.insert(0, {
            "id": f["id"],
            "max_new": f["max_new"],
            "priority": f["priority"],
            "deadline_tick": f["deadline_tick"],
            "adapter_ix": f["adapter_ix"],
            "enq_tick": f["enq_tick"],
            "ttft_done": f["ttft_done"],
        })

    def _admit(self):
        if self.slo:
            self._cancel_expired()
        preempted_now = False
        while True:
            while self.free_rows() > 0:
                ix = self._pick_ix()
                if ix is None:
                    break
                q = self.queue.pop(ix)
                row = self.rows.index(None)  # SimEngine: first free row
                self.emit("Admit", req=q["id"], row=row)
                self.rows[row] = {**q, "tokens": 0, "last": None}
                self.admitted += 1
            # preemption: rows full and a strictly higher class waiting —
            # one victim per tick, retry the loop into the freed row
            if not self.slo or preempted_now or self.free_rows() > 0:
                break
            if not self.queue:
                break
            want = max(_prio(q["priority"]) for q in self.queue)
            cands = [
                (_prio(f["priority"]), -f["enq_tick"], row)
                for row, f in enumerate(self.rows)
                if f is not None and _prio(f["priority"]) < want
            ]
            if not cands:
                break
            self._preempt(min(cands)[2])
            preempted_now = True

    def step(self):
        """One scheduler tick; returns finished request dicts. The clock
        only advances while anything is active (idle = no-op, exactly the
        Rust early return before `ticks += 1`)."""
        self.trace_tick = self.ticks
        self._admit()
        if self.in_flight() == 0:
            return []
        self.ticks += 1
        self.trace_tick = self.ticks
        now = self.ticks
        # sample_gauges mirror: one queue-depth + in-flight pair per
        # counted tick, before the decode events
        self.emit("Gauge", name="queue_depth", value=float(len(self.queue)))
        self.emit("Gauge", name="in_flight", value=float(self.in_flight()))
        done_rows = []
        for row, f in enumerate(self.rows):
            if f is None:
                continue
            self.emit("DecodeStep", row=row)
            self.total_tokens += 1
            f["tokens"] += 1
            if not f["ttft_done"]:
                f["ttft_done"] = True
                self.ttft_ticks.append(now - f["enq_tick"])
                self.req_ttft[f["id"]] = (f["priority"], now - f["enq_tick"])
            if f["last"] is not None:
                self.itl_ticks.append(now - f["last"])
            f["last"] = now
            if f["tokens"] == f["max_new"]:
                done_rows.append(row)
        out = []
        for row in done_rows:
            f = self.rows[row]
            self.rows[row] = None
            self.emit("Finish", req=f["id"], row=row, tokens=f["tokens"])
            d = f["deadline_tick"]
            if d is not None and now > d:
                self.emit("DeadlineMiss", req=f["id"])
                self.deadline_misses += 1
            self.served += 1
            out.append({"id": f["id"], "tokens": f["tokens"]})
        return out

    def drain(self):
        out = []
        while self.pending() > 0 or self.in_flight() > 0:
            out.extend(self.step())
        return out

    def goodput(self):
        return (self.served - self.deadline_misses) / float(
            max(self.served + self.cancelled, 1)
        )

    def server_stats(self):
        """The `serverStats` block `serve --trace` embeds, recomputed
        from the model — the keys trace_report.py --check consumes."""
        return {
            "ticks": self.ticks,
            "served": self.served,
            "rejected": self.rejected,
            "total_tokens": self.total_tokens,
            "preempted": self.preempted,
            "cancelled": self.cancelled,
            "deadline_misses": self.deadline_misses,
            "goodput": self.goodput(),
            "ttft_tick_p50": percentile(self.ttft_ticks, 50.0),
            "ttft_tick_p95": percentile(self.ttft_ticks, 95.0),
            "itl_tick_p50": percentile(self.itl_ticks, 50.0),
            "itl_tick_p95": percentile(self.itl_ticks, 95.0),
        }

    def trace_doc(self):
        return {
            "displayTimeUnit": "ms",
            "traceEvents": [],
            "loramEvents": self.events,
            "otherData": {
                "clock": "tick",
                "schema_version": TRACE_SCHEMA_VERSION,
                "dropped": 0,
            },
            "serverStats": self.server_stats(),
        }


def run_workload(srv, reqs):
    """Mirror of workload.rs::run — step to each arrival tick (idle gaps
    collapse: the clock only advances while work exists), then drain."""
    out = []
    for r in reqs:
        while srv.ticks < r["arrival_tick"] and (
            srv.pending() > 0 or srv.in_flight() > 0
        ):
            out.extend(srv.step())
        srv.enqueue(r)
    out.extend(srv.drain())
    return out


def hi_ttft_p95(srv):
    """High-priority TTFT p95 across the run, for the A/B report."""
    xs = [t for (p, t) in srv.req_ttft.values() if p == "high"]
    return percentile(xs, 95.0)


def run_ab(scenario, n, seed, batch):
    reqs = generate(scenario, n, seed)
    fifo = SimServer(batch, slo=False)
    run_workload(fifo, reqs)
    slo = SimServer(batch, slo=True)
    run_workload(slo, reqs)
    return fifo, slo


def main(argv):
    argv = argv[1:]
    if "--list" in argv:
        for s in SCENARIOS:
            print(s)
        return 0
    pos = [a for a in argv if not a.startswith("-")]
    flags = [a for a in argv if a.startswith("-")]
    scenario = pos[0] if pos else None
    if scenario is None:
        print(__doc__.strip().splitlines()[0])
        print("usage: slo_sim.py [--ab] SCENARIO [-n N] [--seed S] "
              "[--batch B] [--slo] [--fair-rows K] [--out F]")
        print(f"scenarios: {', '.join(SCENARIOS)}")
        return 2

    def opt(name, default):
        if name in argv:
            return int(argv[argv.index(name) + 1])
        return default

    n = opt("-n", 64)
    seed = opt("--seed", 0)
    batch = opt("--batch", 4)
    try:
        if "--ab" in flags:
            fifo, slo = run_ab(scenario, n, seed, batch)
            gf, gs = fifo.goodput(), slo.goodput()
            print(
                f"slo_sim A/B {scenario!r} n={n} seed={seed} batch={batch}:"
            )
            print(
                f"  fifo: goodput {gf:.3f}  misses {fifo.deadline_misses}  "
                f"cancelled {fifo.cancelled}  hi-ttft-p95 "
                f"{hi_ttft_p95(fifo):g}"
            )
            print(
                f"  slo : goodput {gs:.3f}  misses {slo.deadline_misses}  "
                f"cancelled {slo.cancelled}  preempted {slo.preempted}  "
                f"hi-ttft-p95 {hi_ttft_p95(slo):g}"
            )
            if gs <= gf:
                print("slo_sim: FAIL — the SLO scheduler did not beat FIFO "
                      "on goodput-under-SLO")
                return 1
            print("slo_sim: OK — SLO beats FIFO on goodput-under-SLO")
            return 0
        reqs = generate(scenario, n, seed)
        srv = SimServer(
            batch,
            slo="--slo" in flags,
            fair_rows=opt("--fair-rows", None) if "--fair-rows" in argv else None,
        )
        run_workload(srv, reqs)
        doc = srv.trace_doc()
        if "--out" in argv:
            path = argv[argv.index("--out") + 1]
            with open(path, "w") as f:
                json.dump(doc, f, indent=1)
            print(
                f"slo_sim: {scenario!r} n={n} -> {path} "
                f"({len(srv.events)} events, goodput {srv.goodput():.3f})"
            )
        else:
            json.dump(doc, sys.stdout, indent=1)
            print()
        return 0
    except ValueError as e:
        print(f"slo_sim: {e}")
        return 2


if __name__ == "__main__":
    sys.exit(main(sys.argv))

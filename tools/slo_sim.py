#!/usr/bin/env python3
"""Python tick model of the SLO serving scheduler (stdlib only).

An exact mirror of `rust/src/serve.rs::Server` over the instant-prefill
`SimEngine` (one marker token per row per tick, finish at `max_new`):
same admission pick rule, same fairness-cap skip, same one-victim-
per-tick preemption (lowest class, youngest enqueue, lowest row on
ties), same deadline cancellation and miss accounting, and the same
pre-/post-increment tick stamping — so for any workload from
`tools/workload_gen.py` the event stream, the TTFT/ITL tick vectors and
every counter equal what the Rust scheduler produces, event for event.
`python/tests/test_slo_sched.py` pins the same scenario numbers the
`serve.rs` unit tests assert, pre-validating them without cargo.

The emitted trace document has the `serve --trace` shape (`loramEvents`
+ `serverStats`), so `tools/trace_report.py --check` audits the model's
streams under the full conservation-law suite — the `slo-sim` CI lane.

Chaos (§2j): `--chaos SCN` replays a `tools/chaos_gen.py` fault plan
against the model — the same plan `chaos::ChaosEngine` injects — through
the same failure-domain machinery `serve.rs` grew: row faults preempt +
retry with exponential backoff under `--retry-budget`/`--backoff-base`
(budget exhaustion → a terminal `Failed`), engine faults walk the
Healthy → Degraded → Failing health machine, and device loss drains
every survivor as a loud failure. Without a retry budget the first
fault aborts the run (the pre-§2j contract), which is exactly what
`--chaos-ab` measures: retry + isolation vs abort-on-error on the same
storm, gated on offered-load goodput.

Usage:
    python3 tools/slo_sim.py SCENARIO [-n N] [--seed S] [--batch B]
            [--slo] [--fair-rows K] [--chaos CSCN] [--chaos-ticks T]
            [--retry-budget R] [--backoff-base B] [--out trace.json]
    python3 tools/slo_sim.py --ab SCENARIO [-n N] [--seed S] [--batch B]
        # runs FIFO vs SLO on the same stream; exit 1 unless SLO wins
        # on goodput-under-SLO
    python3 tools/slo_sim.py --chaos-ab SCENARIO [-n N] [--seed S]
            [--batch B] [--chaos CSCN] [--chaos-ticks T]
        # retry+isolation vs abort-on-error under the same fault storm;
        # exit 1 unless retry wins on offered-load goodput and loses
        # zero requests silently
"""

import json
import math
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from chaos_gen import FAULT_KINDS, generate as chaos_plan  # noqa: E402
from workload_gen import PRIORITIES, SCENARIOS, generate  # noqa: E402

TRACE_SCHEMA_VERSION = 1


class AbortOnError(RuntimeError):
    """A decode fault with no retry policy — the pre-§2j contract: the
    whole run aborts (what `--chaos-ab` measures against)."""


def percentile(xs, p):
    """rank = (p/100)*(n-1) lerp — same as util::stats / trace_report."""
    if not xs:
        return 0.0
    v = sorted(xs)
    rank = (p / 100.0) * (len(v) - 1)
    lo, hi = math.floor(rank), math.ceil(rank)
    if lo == hi:
        return float(v[lo])
    return v[lo] + (rank - lo) * (v[hi] - v[lo])


def _prio(name):
    return PRIORITIES.index(name)


class SimServer:
    """Mirror of `Server<SimEngine>` with instant admissions: the
    `prefill_begin` path always completes, `can_admit` is always true,
    and decode emits one token per occupied row per tick in row order."""

    def __init__(self, batch, slo=False, fair_rows=None, chaos=None,
                 retry_budget=None, backoff_base=1):
        self.batch = batch
        self.rows = [None] * batch
        self.queue = []
        self.next_id = 0
        self.ticks = 0
        self.slo = slo
        # mirror set_adapter_fair_cap's cap.max(1) clamp
        self.fair_rows = None if fair_rows is None else max(fair_rows, 1)
        self.trace_tick = 0
        self.events = []
        self.admitted = 0
        self.served = 0
        self.rejected = 0
        self.preempted = 0
        self.cancelled = 0
        self.deadline_misses = 0
        self.total_tokens = 0
        self.ttft_ticks = []
        self.itl_ticks = []
        # req id -> (priority name, ttft ticks) for A/B reporting
        self.req_ttft = {}
        # §2j chaos: a chaos_gen plan replayed like chaos::ChaosEngine —
        # armed on the pre-increment tick, at most one fault per tick,
        # stale arms dropped, device loss latched permanently
        self.plan = chaos or []
        self.cursor = 0
        self.armed = None
        self.lost = False
        self.injected = 0
        # §2j retry/backoff policy (mirror of set_retry_policy)
        self.retry_budget = retry_budget
        self.backoff_base = max(backoff_base, 1)
        self.health = "healthy"
        self.clean_ticks = 0
        self.engine_fault_streak = 0
        self.failed = 0
        self.retries = 0
        self.degraded_ticks = 0

    def emit(self, kind, **fields):
        self.events.append(
            {"kind": kind, "tick": self.trace_tick, "wall_ms": 0.0, **fields}
        )

    def pending(self):
        return len(self.queue)

    def in_flight(self):
        return sum(1 for f in self.rows if f is not None)

    def free_rows(self):
        return sum(1 for f in self.rows if f is None)

    def enqueue(self, req):
        """`req` is a workload_gen request dict; returns the id.
        Mirrors enqueue_slo: the absolute deadline is `ticks + rel`."""
        rid = self.next_id
        self.next_id += 1
        rel = req.get("deadline_ticks")
        self.queue.append({
            "id": rid,
            "max_new": max(req["max_new"], 1),  # SimRow budget clamp
            "priority": req.get("priority", "normal"),
            "deadline_tick": None if rel is None else self.ticks + rel,
            "adapter_ix": req.get("adapter_ix"),
            "enq_tick": self.ticks,
            "ttft_done": False,
            "attempts": 0,
            "not_before": 0,
        })
        self.trace_tick = self.ticks
        self.emit("Enqueue", req=rid)
        return rid

    def _pick_ix(self):
        if not self.slo and self.fair_rows is None and self.retry_budget is None:
            return 0 if self.queue else None
        best = None  # (priority ordinal, index)
        for ix, q in enumerate(self.queue):
            if q["not_before"] > self.ticks:
                continue  # §2j retry backoff: not admissible yet
            if self.fair_rows is not None:
                lane = sum(
                    1 for f in self.rows
                    if f is not None and f["adapter_ix"] == q["adapter_ix"]
                )
                if lane >= self.fair_rows:
                    continue
            if best is None or (self.slo and _prio(q["priority"]) > best[0]):
                best = (_prio(q["priority"]), ix)
        return None if best is None else best[1]

    def _cancel_expired(self):
        now = self.ticks
        kept = []
        for q in self.queue:
            d = q["deadline_tick"]
            if d is not None and d <= now:
                self.emit("Cancel", req=q["id"])
                self.cancelled += 1
            else:
                kept.append(q)
        self.queue = kept

    def _preempt(self, row):
        f = self.rows[row]
        self.rows[row] = None
        self.emit("Preempt", req=f["id"], row=row, tokens=f["tokens"])
        self.preempted += 1
        # back to the queue front with the original clocks; the next life
        # restarts its token count but never re-records TTFT
        self.queue.insert(0, {
            "id": f["id"],
            "max_new": f["max_new"],
            "priority": f["priority"],
            "deadline_tick": f["deadline_tick"],
            "adapter_ix": f["adapter_ix"],
            "enq_tick": f["enq_tick"],
            "ttft_done": f["ttft_done"],
            "attempts": f["attempts"],
            "not_before": 0,
        })

    # ---- §2j chaos engine mirror (chaos::ChaosEngine surfaces) ----

    def _begin_tick(self, tick):
        """Mirror of ChaosEngine::begin_tick: drop a stale arm, advance
        the cursor, latch device loss, arm the tick's fault."""
        if self.armed is not None and self.armed["tick"] < tick:
            self.armed = None
        while self.cursor < len(self.plan):
            f = self.plan[self.cursor]
            if f["tick"] > tick:
                break
            self.cursor += 1
            if f["kind_ix"] == 4:
                self.lost = True
            elif f["tick"] == tick:
                self.armed = f

    def _armed_kind(self, kind_ix):
        if self.armed is not None and self.armed["kind_ix"] == kind_ix:
            return self.armed
        return None

    def _can_admit(self):
        """Mirror of ChaosEngine::can_admit over the always-true inner."""
        if self.lost:
            return False
        if self._armed_kind(2) is not None:
            self.armed = None
            self.injected += 1
            return False
        return True

    def _prefill_ok(self):
        """Mirror of ChaosEngine::prefill_begin over the always-Ok inner:
        True = admitted, False = the admission bailed (Reject path)."""
        if self.lost:
            return False
        if self._armed_kind(1) is not None:
            self.armed = None
            self.injected += 1
            return False
        return True

    def _admit(self):
        if self.slo:
            self._cancel_expired()
        admitted_now = 0
        had_err = False
        preempted_now = False
        while True:
            while self.free_rows() > 0:
                # Degraded health shrinks admission to one per tick (§2j)
                if self.health == "degraded" and admitted_now >= 1:
                    break
                ix = self._pick_ix()
                if ix is None:
                    break
                q = self.queue.pop(ix)
                can = self._can_admit()
                if not can and (admitted_now > 0 or self.in_flight() > 0):
                    self.emit("Requeue", req=q["id"])
                    self.queue.insert(ix, q)
                    break
                if not self._prefill_ok():
                    self.emit("Reject", req=q["id"])
                    self.rejected += 1
                    had_err = True
                    continue
                admitted_now += 1
                row = self.rows.index(None)  # SimEngine: first free row
                self.emit("Admit", req=q["id"], row=row)
                self.rows[row] = {**q, "tokens": 0, "last": None}
                self.admitted += 1
            # preemption: rows full and a strictly higher class waiting —
            # one victim per tick, retry the loop into the freed row
            if not self.slo or preempted_now or self.free_rows() > 0:
                break
            if not self.queue:
                break
            want = max(_prio(q["priority"]) for q in self.queue)
            cands = [
                (_prio(f["priority"]), -f["enq_tick"], row)
                for row, f in enumerate(self.rows)
                if f is not None and _prio(f["priority"]) < want
            ]
            if not cands:
                break
            self._preempt(min(cands)[2])
            preempted_now = True
        if (had_err and admitted_now == 0 and self.in_flight() == 0
                and self.retry_budget is None):
            raise AbortOnError(
                "every admission failed with no requests in flight"
            )

    # ---- §2j failure-domain machinery (serve.rs §2j mirror) ----

    def _set_health(self, h):
        if self.health == h:
            return
        if h == "healthy":
            self.emit("Recover")
        else:
            self.emit("Degrade", level=h)
        self.health = h
        self.clean_ticks = 0

    def _fault_row(self, row, kind):
        """Row-scoped fault: retry within budget (preempt + backoff) or
        terminate as a first-class failure."""
        f = self.rows[row]
        self.rows[row] = None
        self.emit("Fault", req=f["id"], row=row, fault=kind)
        attempts = f["attempts"] + 1
        if attempts <= self.retry_budget:
            self.emit("Preempt", req=f["id"], row=row, tokens=f["tokens"])
            self.preempted += 1
            self.emit("Retry", req=f["id"], attempt=attempts)
            self.retries += 1
            backoff = self.backoff_base << min(attempts - 1, 32)
            self.queue.insert(0, {
                "id": f["id"],
                "max_new": f["max_new"],
                "priority": f["priority"],
                "deadline_tick": f["deadline_tick"],
                "adapter_ix": f["adapter_ix"],
                "enq_tick": f["enq_tick"],
                "ttft_done": f["ttft_done"],
                "attempts": attempts,
                "not_before": self.ticks + backoff,
            })
            return []
        self.emit("Failed", req=f["id"], tokens=f["tokens"], attempts=attempts)
        self.failed += 1
        return [{"id": f["id"], "tokens": 0, "failed": True}]

    def _fail_everything(self, kind):
        """Enter failing: every survivor fails loudly — in-flight rows as
        terminal faults, queued requests as zero-token failures."""
        self._set_health("failing")
        out = []
        for row in range(self.batch):
            f = self.rows[row]
            if f is None:
                continue
            self.rows[row] = None
            self.emit("Fault", req=f["id"], row=row, fault=kind)
            self.emit(
                "Failed", req=f["id"], tokens=f["tokens"],
                attempts=f["attempts"] + 1,
            )
            self.failed += 1
            out.append({"id": f["id"], "tokens": 0, "failed": True})
        out.extend(self._fail_queue())
        return out

    def _fail_queue(self):
        out = []
        while self.queue:
            q = self.queue.pop(0)
            self.emit("Failed", req=q["id"], tokens=0, attempts=q["attempts"])
            self.failed += 1
            out.append({"id": q["id"], "tokens": 0, "failed": True})
        return out

    def _decode_fault(self):
        """Mirror of ChaosEngine::decode_step's chaos preamble: the fault
        that fires this tick, or None for a clean decode."""
        if self.lost:
            return {"domain": "lost", "kind": "device-lost", "row": None}
        f = self._armed_kind(0)
        if f is not None:
            self.armed = None
            self.injected += 1
            return {"domain": "row", "kind": FAULT_KINDS[0], "row": f["row"]}
        if self._armed_kind(3) is not None:
            self.armed = None
            self.injected += 1
            return {"domain": "engine", "kind": FAULT_KINDS[3], "row": None}
        return None

    def _on_decode_fault(self, fault):
        if self.retry_budget is None:
            raise AbortOnError(f"chaos: {fault['kind']} with no retry policy")
        if fault["domain"] == "row":
            row = fault["row"]
            if row < self.batch and self.rows[row] is not None:
                return self._fault_row(row, fault["kind"])
            return []  # aimed at an empty row: a harmless lost tick
        if fault["domain"] == "engine":
            self.clean_ticks = 0
            self.engine_fault_streak += 1
            if self.engine_fault_streak >= 3:
                return self._fail_everything(fault["kind"])
            self._set_health("degraded")
            return []
        return self._fail_everything(fault["kind"])

    def step(self):
        """One scheduler tick; returns finished request dicts. The clock
        only advances while anything is active (idle = no-op, exactly the
        Rust early return before `ticks += 1`)."""
        self.trace_tick = self.ticks
        self._begin_tick(self.ticks)
        if self.health == "failing":
            # terminal: fail any late arrivals loudly (§2j)
            return self._fail_queue()
        self._admit()
        if self.in_flight() == 0:
            # §2j: when every queued entry is backing off, let sim time
            # pass so `not_before` unblocks instead of wedging drain
            if (self.retry_budget is not None and self.queue
                    and all(q["not_before"] > self.ticks for q in self.queue)):
                self.ticks += 1
            return []
        self.ticks += 1
        if self.health != "healthy":
            self.degraded_ticks += 1
        self.trace_tick = self.ticks
        now = self.ticks
        # sample_gauges mirror: one queue-depth + in-flight pair per
        # counted tick, before the decode events
        self.emit("Gauge", name="queue_depth", value=float(len(self.queue)))
        self.emit("Gauge", name="in_flight", value=float(self.in_flight()))
        fault = self._decode_fault()
        if fault is not None:
            return self._on_decode_fault(fault)
        # a clean decode tick heals (mirror of the serve.rs Ok arm)
        self.engine_fault_streak = 0
        if self.health == "degraded":
            self.clean_ticks += 1
            if self.clean_ticks >= 3:
                self._set_health("healthy")
        done_rows = []
        for row, f in enumerate(self.rows):
            if f is None:
                continue
            self.emit("DecodeStep", row=row)
            self.total_tokens += 1
            f["tokens"] += 1
            if not f["ttft_done"]:
                f["ttft_done"] = True
                self.ttft_ticks.append(now - f["enq_tick"])
                self.req_ttft[f["id"]] = (f["priority"], now - f["enq_tick"])
            if f["last"] is not None:
                self.itl_ticks.append(now - f["last"])
            f["last"] = now
            if f["tokens"] == f["max_new"]:
                done_rows.append(row)
        out = []
        for row in done_rows:
            f = self.rows[row]
            self.rows[row] = None
            self.emit("Finish", req=f["id"], row=row, tokens=f["tokens"])
            d = f["deadline_tick"]
            if d is not None and now > d:
                self.emit("DeadlineMiss", req=f["id"])
                self.deadline_misses += 1
            self.served += 1
            out.append({"id": f["id"], "tokens": f["tokens"]})
        return out

    def drain(self):
        out = []
        while self.pending() > 0 or self.in_flight() > 0:
            out.extend(self.step())
        return out

    def goodput(self):
        return (self.served - self.deadline_misses) / float(
            max(self.served + self.cancelled + self.failed, 1)
        )

    def server_stats(self):
        """The `serverStats` block `serve --trace` embeds, recomputed
        from the model — the keys trace_report.py --check consumes."""
        return {
            "ticks": self.ticks,
            "served": self.served,
            "rejected": self.rejected,
            "total_tokens": self.total_tokens,
            "preempted": self.preempted,
            "cancelled": self.cancelled,
            "deadline_misses": self.deadline_misses,
            "failed": self.failed,
            "retries": self.retries,
            "degraded_ticks": self.degraded_ticks,
            "goodput": self.goodput(),
            "ttft_tick_p50": percentile(self.ttft_ticks, 50.0),
            "ttft_tick_p95": percentile(self.ttft_ticks, 95.0),
            "itl_tick_p50": percentile(self.itl_ticks, 50.0),
            "itl_tick_p95": percentile(self.itl_ticks, 95.0),
        }

    def trace_doc(self):
        return {
            "displayTimeUnit": "ms",
            "traceEvents": [],
            "loramEvents": self.events,
            "otherData": {
                "clock": "tick",
                "schema_version": TRACE_SCHEMA_VERSION,
                "dropped": 0,
            },
            "serverStats": self.server_stats(),
        }


def run_workload(srv, reqs):
    """Mirror of workload.rs::run — step to each arrival tick (idle gaps
    collapse: the clock only advances while work exists), then drain."""
    out = []
    for r in reqs:
        while srv.ticks < r["arrival_tick"] and (
            srv.pending() > 0 or srv.in_flight() > 0
        ):
            out.extend(srv.step())
        srv.enqueue(r)
    out.extend(srv.drain())
    return out


def hi_ttft_p95(srv):
    """High-priority TTFT p95 across the run, for the A/B report."""
    xs = [t for (p, t) in srv.req_ttft.values() if p == "high"]
    return percentile(xs, 95.0)


def run_ab(scenario, n, seed, batch):
    reqs = generate(scenario, n, seed)
    fifo = SimServer(batch, slo=False)
    run_workload(fifo, reqs)
    slo = SimServer(batch, slo=True)
    run_workload(slo, reqs)
    return fifo, slo


def goodput_offered(srv, n):
    """Goodput against *offered* load: (served - misses) / n. The A/B
    gate uses this because abort-on-error's tiny completed set would
    flatter its plain (completion-denominator) goodput."""
    return (srv.served - srv.deadline_misses) / float(max(n, 1))


def run_chaos_ab(scenario, n, seed, batch, chaos_scn, chaos_ticks):
    """Retry+isolation vs abort-on-error under the same fault plan (§2j).
    Returns (retry_srv, abort_srv, abort_error_or_None)."""
    reqs = generate(scenario, n, seed)
    plan = chaos_plan(chaos_scn, chaos_ticks, seed)
    retry = SimServer(batch, chaos=plan, retry_budget=2, backoff_base=1)
    run_workload(retry, reqs)
    abort = SimServer(batch, chaos=plan, retry_budget=None)
    err = None
    try:
        run_workload(abort, reqs)
    except AbortOnError as e:
        err = e
    return retry, abort, err


def main(argv):
    argv = argv[1:]
    if "--list" in argv:
        for s in SCENARIOS:
            print(s)
        return 0
    pos = [a for a in argv if not a.startswith("-")]
    flags = [a for a in argv if a.startswith("-")]
    scenario = pos[0] if pos else None
    if scenario is None:
        print(__doc__.strip().splitlines()[0])
        print("usage: slo_sim.py [--ab|--chaos-ab] SCENARIO [-n N] "
              "[--seed S] [--batch B] [--slo] [--fair-rows K] "
              "[--chaos CSCN] [--chaos-ticks T] [--retry-budget R] "
              "[--backoff-base B] [--out F]")
        print(f"scenarios: {', '.join(SCENARIOS)}")
        return 2

    def opt(name, default):
        if name in argv:
            return int(argv[argv.index(name) + 1])
        return default

    def sopt(name, default):
        if name in argv:
            return argv[argv.index(name) + 1]
        return default

    n = opt("-n", 64)
    seed = opt("--seed", 0)
    batch = opt("--batch", 4)
    chaos_scn = sopt("--chaos", None)
    chaos_ticks = opt("--chaos-ticks", 64)
    try:
        if "--chaos-ab" in flags:
            retry, abort, err = run_chaos_ab(
                scenario, n, seed, batch, chaos_scn or "fault-storm",
                chaos_ticks,
            )
            go_r, go_a = goodput_offered(retry, n), goodput_offered(abort, n)
            print(
                f"slo_sim chaos A/B {scenario!r} x "
                f"{chaos_scn or 'fault-storm'!r} n={n} seed={seed} "
                f"batch={batch}:"
            )
            print(
                f"  retry: goodput-offered {go_r:.3f}  served {retry.served}"
                f"  failed {retry.failed}  retries {retry.retries}"
                f"  rejected {retry.rejected}  injected {retry.injected}"
            )
            print(
                f"  abort: goodput-offered {go_a:.3f}  served {abort.served}"
                f"  aborted {'yes: ' + str(err) if err else 'no'}"
            )
            resolved = (retry.served + retry.failed + retry.cancelled
                        + retry.rejected)
            if resolved != n:
                print(
                    f"slo_sim: FAIL — retry arm lost requests silently "
                    f"({resolved} of {n} resolved)"
                )
                return 1
            if go_r <= go_a:
                print("slo_sim: FAIL — retry+isolation did not beat "
                      "abort-on-error on offered-load goodput")
                return 1
            print("slo_sim: OK — retry+isolation beats abort-on-error, "
                  "zero requests lost silently")
            return 0
        if "--ab" in flags:
            fifo, slo = run_ab(scenario, n, seed, batch)
            gf, gs = fifo.goodput(), slo.goodput()
            print(
                f"slo_sim A/B {scenario!r} n={n} seed={seed} batch={batch}:"
            )
            print(
                f"  fifo: goodput {gf:.3f}  misses {fifo.deadline_misses}  "
                f"cancelled {fifo.cancelled}  hi-ttft-p95 "
                f"{hi_ttft_p95(fifo):g}"
            )
            print(
                f"  slo : goodput {gs:.3f}  misses {slo.deadline_misses}  "
                f"cancelled {slo.cancelled}  preempted {slo.preempted}  "
                f"hi-ttft-p95 {hi_ttft_p95(slo):g}"
            )
            if gs <= gf:
                print("slo_sim: FAIL — the SLO scheduler did not beat FIFO "
                      "on goodput-under-SLO")
                return 1
            print("slo_sim: OK — SLO beats FIFO on goodput-under-SLO")
            return 0
        reqs = generate(scenario, n, seed)
        srv = SimServer(
            batch,
            slo="--slo" in flags,
            fair_rows=opt("--fair-rows", None) if "--fair-rows" in argv else None,
            chaos=chaos_plan(chaos_scn, chaos_ticks, seed) if chaos_scn else None,
            retry_budget=(
                opt("--retry-budget", None) if "--retry-budget" in argv else None
            ),
            backoff_base=opt("--backoff-base", 1),
        )
        run_workload(srv, reqs)
        doc = srv.trace_doc()
        if "--out" in argv:
            path = argv[argv.index("--out") + 1]
            with open(path, "w") as f:
                json.dump(doc, f, indent=1)
            print(
                f"slo_sim: {scenario!r} n={n} -> {path} "
                f"({len(srv.events)} events, goodput {srv.goodput():.3f})"
            )
        else:
            json.dump(doc, sys.stdout, indent=1)
            print()
        return 0
    except ValueError as e:
        print(f"slo_sim: {e}")
        return 2


if __name__ == "__main__":
    sys.exit(main(sys.argv))
